"""Function-graph execution of the High-Low protocol (§III serverless view).

The paper frames the pipeline as serverless *functions* ("model inference",
re-encode, region-classify) orchestrated across client/fog/cloud.  This
module makes that literal: the protocol's stage functions are registered in
a :class:`~repro.serving.registry.FunctionRegistry` under tier-qualified
names and dispatched through :class:`~repro.serving.executor.Executor` /
:class:`~repro.serving.router.Router`:

  ``fog.encode_low``        quality control on the per-camera fog node
  ``cloud.detect``          heavy detector — **batched across streams**
  ``fog.classify_regions``  HQ crop + one-vs-all classify + merge
  ``hitl.collect``          §V feedback collection + incremental update

Execution is **event-driven**: a priority queue of per-stream events
(ingest -> flush -> finalize) replaces the old coordinator's scalar clock,
so N camera streams advance concurrently on one simulated timeline.  The
cloud-detector stage runs through a :class:`CrossStreamBatcher` that packs
frames from concurrent chunks into padded jit'd calls (Tangram-style
batched serverless inference) and feeds the *real* queue depth to the
autoscaler on every dispatch.  At fleet scale the event loop is no longer
one heap: :class:`~repro.serving.shards.ShardedScheduler` runs K of these
schedulers over disjoint stream sets on a merged timeline, and with a
claim-check :class:`~repro.serving.ingest.ArtifactStore` attached the
queued events carry payload *references* instead of frame tensors —
resolved once per flush, at assembly time (see ``_dispatch``).

The serving plane is **SLO-aware and multi-replica**: streams carry a
per-chunk latency SLO (deadline-driven flush — the batch is held open only
while the tightest pending deadline can still be met given the estimated
service time) and a fair-queueing weight (WFQ batch-assembly order), each
flush is sharded into frame-balanced sub-batches routed concurrently
across the :class:`~repro.serving.router.Router`'s health-checked detector
replicas, the autoscaler can add/remove whole replicas
(``scale_unit="replicas"``), and a replica that dies mid-run has its
sub-batch re-queued to survivors (or the fog fallback) with no chunk lost.

The default ``hot_path="fused"`` keeps the detect->split->classify dataflow
**device-resident**: ``encode_low`` output never round-trips through numpy,
cross-stream packing is a device-side concat+pad, the cloud stage is the
fused ``cloud.detect_split`` (one jit dispatch and **one** blocking
device->host read — the proposal-validity mask — per flush, instead of a
``block_until_ready`` plus two scalar syncs per chunk), the fog stage is
the compacted ``fog.classify_batched`` (only the flush's valid proposals
are gathered into one bucketed crop batch and classified cross-stream with
per-stream readouts, scattered back into the full result grid), per-stream
readouts are uploaded once and refreshed only on hot-swap/learner update,
and chunk results stay device-side futures queued in ``_inflight`` until
their finalize event drains them — so flush k's detect overlaps flush
k-1's host-side result materialization.  ``hot_path="sync"`` preserves the
pre-fusion synchronous path (the benchmark baseline).  Both paths are
bit-identical to ``HighLowProtocol.process_chunk`` on a single stream.

With one stream and a zero batching window the event order degenerates to
the strict sequential path, and because the stage functions agree
bit-for-bit, results are identical to ``HighLowProtocol.process_chunk``.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import sys
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol as protocol_mod
from repro.core import regions as reg
from repro.core.bandwidth import LatencyBreakdown, NetworkModel
from repro.core.hitl import OracleAnnotator
from repro.core.protocol import ChunkResult, HighLowProtocol
from repro.serving.batching import (CrossStreamBatcher, DetectRequest,
                                    pack_frames, pack_frames_device)
from repro.serving.executor import Executor
from repro.serving.ingest import (ArtifactCorrupted, ArtifactStore,
                                  ClaimCheck, content_key)
from repro.serving.monitor import Monitor
from repro.serving.registry import Dispatcher, FunctionRegistry, ModelZoo
from repro.serving.router import Router
from repro.serving.tenancy import TenantChunkResult

STAGE_ENCODE = "fog.encode_low"
STAGE_DETECT = "cloud.detect"
STAGE_DETECT_SPLIT = "cloud.detect_split"      # fused detect + §IV.B split
STAGE_DETECT_SPLIT_DON = "cloud.detect_split_donated"  # donates the batch
STAGE_DETECT_SPLIT_DYN = "cloud.detect_split_dynamic"  # per-frame thetas
STAGE_CLASSIFY = "fog.classify_regions"
STAGE_CLASSIFY_BATCH = "fog.classify_batched"  # compacted cross-stream
STAGE_CLASSIFY_ENS = "fog.classify_ensemble"   # Eq. 9 snapshot ensemble
STAGE_CLASSIFY_ENS_BATCH = "fog.classify_ensemble_batched"
STAGE_CLASSIFY_VIEW = "fog.classify_view"      # per-stream slice accounting
STAGE_COLLECT = "hitl.collect"
STAGES = (STAGE_ENCODE, STAGE_DETECT, STAGE_DETECT_SPLIT,
          STAGE_DETECT_SPLIT_DON, STAGE_DETECT_SPLIT_DYN, STAGE_CLASSIFY,
          STAGE_CLASSIFY_BATCH, STAGE_CLASSIFY_ENS, STAGE_CLASSIFY_ENS_BATCH,
          STAGE_CLASSIFY_VIEW, STAGE_COLLECT)


# ---------------------------------------------------------------------------
# The graph: protocol stages as registered serverless functions
# ---------------------------------------------------------------------------
@dataclass
class VideoFunctionGraph:
    """Registers the High-Low stages + models into the serving substrate."""
    protocol: HighLowProtocol
    det_params: Any
    clf_params: Any
    registry: FunctionRegistry = field(default_factory=FunctionRegistry)
    zoo: ModelZoo = field(default_factory=ModelZoo)

    def __post_init__(self):
        p = self.protocol
        self.registry.register(STAGE_ENCODE, self._encode, kind="preprocess",
                               tier="fog")
        self.registry.register(STAGE_DETECT, self._detect, kind="inference",
                               tier="cloud", batchable=True)
        self.registry.register(STAGE_DETECT_SPLIT, self._detect_split,
                               kind="inference", tier="cloud",
                               batchable=True, fused=True)
        self.registry.register(STAGE_DETECT_SPLIT_DON,
                               self._detect_split_donated,
                               kind="inference", tier="cloud",
                               batchable=True, fused=True)
        self.registry.register(STAGE_DETECT_SPLIT_DYN,
                               self._detect_split_dynamic,
                               kind="inference", tier="cloud",
                               batchable=True, fused=True)
        self.registry.register(STAGE_CLASSIFY, self._classify,
                               kind="inference", tier="fog")
        self.registry.register(STAGE_CLASSIFY_BATCH, self._classify_batched,
                               kind="inference", tier="fog", batchable=True)
        self.registry.register(STAGE_CLASSIFY_ENS, self._classify_ensemble,
                               kind="inference", tier="fog", ensemble=True)
        self.registry.register(STAGE_CLASSIFY_ENS_BATCH,
                               self._classify_ensemble_batched,
                               kind="inference", tier="fog", batchable=True,
                               ensemble=True)
        # accounting stage: a fog node's share of the batched classify is a
        # lazy device-side slice of the shared result (no compute)
        self.registry.register(STAGE_CLASSIFY_VIEW, lambda views: views,
                               kind="postprocess", tier="fog")
        self.registry.register(STAGE_COLLECT, self._collect,
                               kind="postprocess", tier="fog")
        self.zoo.register("cloud-detector", self.det_params, p.det_cfg)
        self.zoo.register("fog-classifier", self.clf_params, p.clf_cfg)
        self.dispatcher = Dispatcher(self.registry, self.zoo)
        self.dispatcher.dispatch("cloud", STAGE_DETECT)
        self.dispatcher.dispatch("cloud", STAGE_DETECT_SPLIT)
        self.dispatcher.dispatch("cloud", STAGE_DETECT_SPLIT_DON)
        self.dispatcher.dispatch("cloud", STAGE_DETECT_SPLIT_DYN)
        self.dispatcher.dispatch("cloud", "cloud-detector")
        for name in (STAGE_ENCODE, STAGE_CLASSIFY, STAGE_CLASSIFY_BATCH,
                     STAGE_CLASSIFY_ENS, STAGE_CLASSIFY_ENS_BATCH,
                     STAGE_CLASSIFY_VIEW, STAGE_COLLECT, "fog-classifier"):
            self.dispatcher.dispatch("fog", name)

    # -- stage callables (close over configs/params) ------------------------
    def _encode(self, frames_hq):
        return protocol_mod.encode_low(self.protocol.pcfg,
                                       jnp.asarray(frames_hq))

    def _detect(self, frames):
        return protocol_mod.detect_regions(self.protocol.det_cfg,
                                           self.det_params, frames)

    def _detect_split(self, frames):
        return protocol_mod.detect_split(self.protocol.det_cfg,
                                         self.protocol.pcfg,
                                         self.det_params, frames)

    def _detect_split_donated(self, frames):
        return protocol_mod.detect_split_donated(self.protocol.det_cfg,
                                                 self.protocol.pcfg,
                                                 self.det_params, frames)

    def _detect_split_dynamic(self, frames, theta_cls, theta_loc):
        return protocol_mod.detect_split_dynamic(
            self.protocol.det_cfg, self.protocol.pcfg, self.det_params,
            frames, theta_cls, theta_loc)

    def _classify_batched(self, frames_hq, split, Ws, idxs):
        return protocol_mod.classify_compacted(
            self.protocol.clf_cfg, self.protocol.pcfg, self.clf_params, Ws,
            frames_hq, split, idxs)

    def _classify(self, frames_hq, split, W):
        return protocol_mod.classify_regions(
            self.protocol.clf_cfg, self.protocol.pcfg, self.clf_params, W,
            frames_hq, split)

    def _classify_ensemble(self, frames_hq, split, snaps, omega):
        return protocol_mod.classify_ensemble(
            self.protocol.clf_cfg, self.protocol.pcfg, self.clf_params,
            snaps, omega, frames_hq, split)

    def _classify_ensemble_batched(self, frames_hq, split, snaps, omegas,
                                   idxs):
        return protocol_mod.classify_compacted_ensemble(
            self.protocol.clf_cfg, self.protocol.pcfg, self.clf_params,
            snaps, omegas, frames_hq, split, idxs)

    def _collect(self, stream: "StreamState", chunk, res: ChunkResult) -> int:
        """HITL feedback for one finished chunk; returns 1 on a W update."""
        learner = stream.learner
        annotator = stream.annotator
        for t in range(chunk.frames.shape[0]):
            idx = np.nonzero(res.prop_valid[t])[0]
            if not len(idx):
                continue
            labels = annotator.label_regions(
                res.prop_boxes[t][idx], chunk.gt_boxes[t], chunk.gt_labels[t])
            for i, lab in zip(idx, labels):
                # skip BACKGROUND (inspected, no object) and UNLABELED
                # (annotator budget exhausted — never inspected)
                if lab >= 0:
                    learner.collect(res.fog_features[t, i], int(lab))
        newW, updated = learner.maybe_update(stream.W_device())
        if updated:
            stream.W = np.asarray(newW)   # fog model-cache refresh
            return 1
        return 0


# ---------------------------------------------------------------------------
# Per-stream state
# ---------------------------------------------------------------------------
@dataclass
class StreamState:
    """One camera stream: its fog node, model cache, and HITL state.

    ``slo`` is the stream's end-to-end per-chunk latency target (seconds,
    simulated; None = best-effort), and ``weight`` its fair-queueing weight —
    a high-weight camera's chunks preempt backlog from bulk streams in the
    cross-stream batcher."""
    name: str
    W: np.ndarray
    fog_exec: Executor
    learner: Any = None
    annotator: Any = None
    slo: Optional[float] = None
    weight: float = 1.0
    # owning TenantSpec (tenancy.py); None = the implicit default tenant
    # running the High-Low pipeline — the exact pre-tenancy code paths.
    # A tenant with a custom pipeline routes this stream's flushes through
    # ``_dispatch_tenant`` instead of the detect/classify hot path.
    tenant: Any = None
    clock: float = 0.0
    busy: bool = False
    # adaptive SLO headroom: EWMA of observed deadline attainment drives the
    # per-stream margin between its configured bounds (high attainment ->
    # tighter margin -> more batching; misses -> margin widens fast)
    slo_margin: float = 0.1
    att_ewma: float = 1.0
    # owning shard scheduler (ShardedScheduler): a finalize that runs on a
    # stealing shard must hand the stream's next ingest back to its owner's
    # event loop, not the thief's.  None = the single-scheduler case.
    owner: Any = None
    # per-site detector thresholds (drift adaptation): None = the global
    # ProtocolConfig value, so defaults stay bit-compatible.  A flush whose
    # streams all use defaults takes the static fused stage; any override
    # routes through cloud.detect_split_dynamic with per-frame thetas.
    theta_cls: Optional[float] = None
    theta_loc: Optional[float] = None
    pending: Deque[Tuple[Any, bool]] = field(default_factory=deque)
    results: List[Tuple[Any, ChunkResult, str]] = field(default_factory=list)
    # Eq. 9 ensemble serving: when set, the stream's classify stage scores
    # crops against the whole snapshot lineage (snaps (T, d+1, C) weighted
    # by omega (T,)) instead of the single readout W.  ``W`` stays the
    # latest-snapshot readout — the learning plane keeps rescoring label
    # candidates against it — and a later W hot-swap supersedes (clears)
    # the ensemble.
    snaps: Optional[np.ndarray] = None
    omega: Optional[np.ndarray] = None
    # device-resident readout cache: W is uploaded once and re-uploaded only
    # when the host-side array object changes (hot-swap / learner update),
    # not per chunk.  Identity tracking rather than a setter keeps every
    # existing `stream.W = ...` call site correct.
    w_uploads: int = 0
    _W_dev: Any = None
    _W_src: Any = None
    e_uploads: int = 0
    _E_dev: Any = None
    _E_src: Any = None

    def W_device(self):
        if self._W_dev is None or self._W_src is not self.W:
            self._W_dev = jnp.asarray(self.W)
            self._W_src = self.W
            self.w_uploads += 1
        return self._W_dev

    @property
    def ensemble(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        if self.snaps is None:
            return None
        return self.snaps, self.omega

    def set_ensemble(self, snaps, omega) -> None:
        snaps = np.asarray(snaps)
        omega = np.asarray(omega, snaps.dtype)
        assert snaps.ndim == 3 and omega.shape == (snaps.shape[0],)
        self.snaps, self.omega = snaps, omega

    def clear_ensemble(self) -> None:
        self.snaps = self.omega = None
        self._E_dev = self._E_src = None

    def ensemble_device(self):
        """(snaps, omega) uploaded once per set_ensemble, identity-cached
        like ``W_device``."""
        if self._E_dev is None or self._E_src is not self.snaps:
            self._E_dev = (jnp.asarray(self.snaps), jnp.asarray(self.omega))
            self._E_src = self.snaps
            self.e_uploads += 1
        return self._E_dev


# ---------------------------------------------------------------------------
# Per-field lazy flush results
# ---------------------------------------------------------------------------
class _FlushBundle:
    """One flush's device-side results, materialized per *field* on demand.

    A field's first access downloads its device buffer once for the whole
    flush (id-deduped: the detector boxes back ``acc_boxes`` AND
    ``merged["boxes"]`` — one buffer, one copy); every chunk then slices
    numpy views.  Fields nothing reads are never downloaded — a HITL-off
    run finalizes without ever paying for ``fog_features``."""

    def __init__(self, split, merged, stats: dict, field_downloads: dict):
        self.split, self.merged = split, merged
        self._stats = stats
        self._field_downloads = field_downloads
        self._cache: Dict[int, np.ndarray] = {}
        self._touched = False
        # retention bookkeeping (GraphScheduler.max_retained_bundles):
        # chunks of this flush not yet finalized, and the id-deduped bytes
        # of the device buffers this bundle keeps alive while unsealed
        self.pending = 0
        self.sealed = False
        seen: Dict[int, int] = {}
        for v in (list(merged.values())
                  + [getattr(split, f) for f in split._fields]):
            if not isinstance(v, np.ndarray):
                seen[id(v)] = v.nbytes
        self.device_bytes = sum(seen.values())

    def field(self, name: str) -> np.ndarray:
        if self.sealed:
            arr = self._host.get(name)
            if arr is None:
                raise RuntimeError(
                    f"field {name!r} first accessed after its flush bundle "
                    "was sealed (max_retained_bundles exceeded); consume "
                    "results at finalize or raise the retention cap")
            return arr
        src = (self.merged[name] if name in self.merged
               else getattr(self.split, name))
        if isinstance(src, np.ndarray):
            return src                 # already materialized + swapped in
        arr = self._cache.get(id(src))
        if arr is None:
            arr = self._cache[id(src)] = np.asarray(src)
            self._field_downloads[name] = (
                self._field_downloads.get(name, 0) + 1)
            if not self._touched:
                self._touched = True
                self._stats["result_downloads"] += 1
        if name in self.merged:
            # swap the host copy in for the device ref so the downloaded
            # buffer can free — the big per-flush grids (fog_features,
            # fog_scores) live only in ``merged``; split fields stay
            # device-side because the RegionSplit tuple aliases them
            self.merged[name] = arr
        return arr

    def seal(self) -> None:
        """Drop every device reference this bundle holds.

        Fields already downloaded stay available (the host copies move to
        ``_host``); a *first* access after sealing raises — by then the
        scheduler has decided this flush's device memory must free.  Called
        only on fully-finalized bundles past the retention cap."""
        if self.sealed:
            return
        host: Dict[str, np.ndarray] = {}
        for name, v in self.merged.items():
            if isinstance(v, np.ndarray):
                host[name] = v
        for name in self.split._fields:
            src = getattr(self.split, name)
            if isinstance(src, np.ndarray):
                host[name] = src
            else:
                arr = self._cache.get(id(src))
                if arr is not None:
                    host[name] = arr
        self._host = host
        self.split = self.merged = None
        self._cache.clear()
        self.sealed = True


class LazyChunkResult:
    """Duck-typed :class:`~repro.core.protocol.ChunkResult` whose array
    fields materialize from the flush bundle on first attribute access.

    Scalars (bytes, latency, frame counts) are eager — the scheduler's
    bookkeeping reads them on the finalize path — while the arrays stay
    device-side until a consumer (F1 evaluation, the learning plane, a
    test) actually touches them.  Once read, the numpy slice is cached on
    the instance, so repeated access costs one dict hit."""

    _ARRAY_FIELDS = frozenset((
        "boxes", "labels", "valid", "source", "fog_features", "fog_scores",
        "prop_boxes", "prop_valid"))

    def __init__(self, bundle: _FlushBundle, sl: slice, *, wan_bytes: float,
                 coord_bytes: float, cloud_frames: int, latency):
        self._bundle, self._sl = bundle, sl
        self.wan_bytes = float(wan_bytes)
        self.coord_bytes = float(coord_bytes)
        self.cloud_frames = cloud_frames
        self.latency = latency

    def __getattr__(self, name: str):
        # only reached when normal lookup misses: the lazy array fields
        if name not in LazyChunkResult._ARRAY_FIELDS:
            raise AttributeError(name)
        val = self._bundle.field(name)[self._sl]
        setattr(self, name, val)        # cache: __getattr__ never re-fires
        return val


# ---------------------------------------------------------------------------
# Event-driven scheduler
# ---------------------------------------------------------------------------
class GraphScheduler:
    """Priority-queue scheduler over the function graph.

    Events: ``ingest`` (chunk enters its stream's fog node), ``flush``
    (cross-stream batcher dispatches the cloud detector), ``finalize``
    (chunk result lands; HITL runs; the stream pulls its next chunk).
    """

    def __init__(self, graph: VideoFunctionGraph, *,
                 network: Optional[NetworkModel] = None,
                 monitor: Optional[Monitor] = None,
                 batcher: Optional[CrossStreamBatcher] = None,
                 cloud_devices: int = 1, cloud_replicas: int = 1,
                 autoscaler=None, scale_unit: str = "devices",
                 deadline_batching: bool = True, slo_margin: float = 0.1,
                 adaptive_margin: bool = True,
                 margin_bounds: Tuple[float, float] = (0.05, 0.5),
                 margin_alpha: float = 0.25,
                 cold_start_s: float = 0.0,
                 hot_path: str = "fused",
                 crop_buckets: Tuple[int, ...] = (4, 8, 16, 32, 64, 128),
                 max_retained_bundles: Optional[int] = 256,
                 fault=None, fallback_fn: Optional[Callable] = None,
                 hedging: bool = True, hedge_slack: float = 0.1,
                 router: Optional[Router] = None,
                 seq_counter=None,
                 store: Optional[ArtifactStore] = None,
                 pick_policy: str = "least",
                 cost_model=None,
                 fog_queueing: bool = False,
                 hitl_cost_s: float = 0.0,
                 warm_pool=None):
        assert hot_path in ("fused", "sync")
        proto = graph.protocol
        self.graph = graph
        self.network = network or proto.network
        self.monitor = monitor or Monitor()
        # explicit None check: an empty batcher is falsy (it has __len__)
        self.batcher = (batcher if batcher is not None
                        else CrossStreamBatcher(max_chunks=1, window=0.0))
        if self.batcher.service_model is None:
            # deadline-driven flush needs an estimate of batch service time
            self.batcher.service_model = proto.cloud.detect_time

        def _make_replica(i: int) -> Executor:
            return Executor("cloud" if i == 0 else f"cloud-{i}",
                            graph.registry, proto.cloud,
                            num_devices=cloud_devices)

        if router is not None:
            # sharded mode: every shard dispatches into ONE shared detector
            # replica pool (and one autoscaler) instead of building its own
            self.router = router
            self.cloud_executor = router.replicas[0].executor
        else:
            replicas = [_make_replica(i)
                        for i in range(max(1, cloud_replicas))]
            self.cloud_executor = replicas[0]   # primary (never retired)
            self.router = Router(replicas, monitor=self.monitor,
                                 autoscaler=autoscaler,
                                 scale_unit=scale_unit,
                                 replica_factory=_make_replica,
                                 cold_start_s=cold_start_s,
                                 pick_policy=pick_policy)
        self.autoscaler = autoscaler
        # claim-check plane: when set, _arrive publishes the encoded chunk
        # here and the batcher queue holds only ClaimCheck references; the
        # payloads are resolved (and the claims released) in _dispatch
        self.store = store
        self.deadline_batching = deadline_batching
        # headroom fraction of the SLO held back when deriving the detect
        # deadline: estimates (service time, downstream work, device wait)
        # carry error, and a batch held open to the exact deadline misses
        # on any slip.  ``slo_margin`` is each stream's *initial* margin;
        # with ``adaptive_margin`` it then tracks an EWMA of the stream's
        # observed deadline attainment between ``margin_bounds``.
        self.slo_margin = slo_margin
        self.adaptive_margin = adaptive_margin
        self.margin_bounds = margin_bounds
        self.margin_alpha = margin_alpha
        # continual-learning plane hook (ContinualLearningPlane.attach)
        self.plane = None
        self.fault = fault
        self.fallback_fn = fallback_fn
        # --- chaos plane ---------------------------------------------------
        # hedged dispatch: when the primary replica's service-rate EWMA says
        # this sub-batch will straggle past the flush's detect deadline, a
        # speculative duplicate is booked on the best alternate replica and
        # whichever completion comes first wins.  The primary wins exact
        # ties (same deterministic (t, seq) discipline as sharding) and the
        # decision is gated on an attached fault schedule, so a fault-free
        # or idle-injector run never hedges and stays bitwise-identical.
        self.hedging = hedging
        self.hedge_slack = hedge_slack
        # flapped-replica readmission: health probes with exponential
        # backoff, only for outages the injector marks transient
        self.probe_base = 0.05
        self.probe_max = 1.0
        self._probing: set = set()
        # reported unconditionally (zeros on fault-free runs) so plain and
        # idle-injector throughput reports stay key-for-key identical
        self.chaos_stats = {"hedges": 0, "hedge_wins": 0,
                            "hedge_busy_s": 0.0, "probes": 0, "readmits": 0,
                            "requeues": 0, "corruptions_repaired": 0}
        # estimate of the post-detect work (coords download + fog classify)
        # a chunk still faces; the detect deadline is the stream SLO minus
        # this.  Tracked as a fast-up/slow-down EWMA of observed values so
        # the flush policy stays conservative: under-holding a batch only
        # costs batching efficiency, over-holding misses the SLO.
        self._downstream_est = (self.network.wan_time(0.0)
                                + proto.fog.classify_time(8))
        self.streams: Dict[str, StreamState] = {}
        self._events: List[Tuple[float, int, str, dict]] = []
        # shards share one counter so same-time events across shard heaps
        # keep a global, deterministic tie-break order
        self._seq = seq_counter if seq_counter is not None \
            else itertools.count()
        # event-loop wall accounting: step_wall_s brackets every step();
        # model_wall_s brackets _dispatch (payload assembly + model calls),
        # so (step - model) / finalizes is the per-chunk *scheduling*
        # overhead — the flatness metric gated by bench_shard_scale
        self.sched_stats = {"events": 0, "finalizes": 0,
                            "step_wall_s": 0.0, "model_wall_s": 0.0}
        # wall-clock accounting for the jit'd detect stage (throughput lever)
        self.detect_stats = {"calls": 0, "frames": 0, "padded_frames": 0,
                             "wall_s": 0.0}
        # (start, service) of every detect dispatch, held here because a
        # replica retired by scale-down takes its ExecutionRecords with it
        self._detect_windows: List[Tuple[float, float]] = []
        # --- device-resident hot path -------------------------------------
        # "fused": one cloud.detect_split dispatch + ONE blocking host read
        # (the validity mask) per flush, compacted cross-stream classify,
        # results kept as device futures until their finalize event.
        # "sync": the pre-fusion baseline (per-chunk split + scalar syncs +
        # full-budget classify + block_until_ready) for A/B benchmarking.
        self.hot_path = hot_path
        self.crop_buckets = crop_buckets
        # donate the packed detect batch to the fused jit on accelerator
        # backends: the multi-request concat buffer is dispatch-owned and
        # dead after the call, so XLA may reuse it in place.  CPU leaves
        # donation a warning-level no-op, so CI keeps the plain stage; the
        # single-request pass-through (an encode-output / store-held array)
        # is never donated regardless of the flag.
        self.donate_detect = (hot_path == "fused"
                              and jax.default_backend() != "cpu")
        # shared executor for the compacted cross-stream classify call (the
        # per-stream share is accounted on each stream's own fog executor)
        self.fog_batch_exec = Executor("fog-batch", graph.registry, proto.fog)
        # bounded memo for the stacked ensemble upload, keyed on the
        # flush's readout-group composition: deadline-driven batching
        # produces a handful of recurring flush mixes, each of which
        # should upload its (snaps, omegas) device stack once.  Values
        # hold strong refs to the source arrays, so an id in a live key
        # can never be recycled.  A hot-swap changes a source's identity
        # and naturally misses.
        self._ens_cache: Dict[Tuple[int, ...],
                              Tuple[List[Any], Tuple[Any, Any]]] = {}
        self._ens_cache_cap = 16
        # device-side results awaiting materialization at their finalize
        # event — the in-flight future queue that lets flush k's detect
        # overlap flush k-1's host-side result handling
        self._inflight: Deque[dict] = deque()
        # host_syncs counts *blocking* device->host reads on the dispatch
        # path (the reads that stall the accelerator feed; the per-chunk
        # result downloads happen later, at finalize, and are counted as
        # result_downloads)
        self.hot_path_stats = {"flushes": 0, "host_syncs": 0,
                               "result_downloads": 0, "crops_classified": 0,
                               "crops_budget": 0, "inflight_peak": 0,
                               "ensemble_flushes": 0, "ensemble_uploads": 0,
                               "bundles_sealed": 0, "bundles_retained_peak": 0,
                               "bundle_bytes": 0, "bundle_bytes_peak": 0}
        # bounded flush-bundle retention: a long-running service finalizes
        # far more flushes than any consumer revisits, and each unsealed
        # bundle pins its flush's device buffers.  Once more than
        # ``max_retained_bundles`` bundles are alive, the oldest fully-
        # finalized ones are sealed (device refs dropped; downloaded host
        # copies kept) so device residency stays flat.  ``None`` disables.
        self.max_retained_bundles = max_retained_bundles
        self._bundles: Deque[_FlushBundle] = deque()
        # per-field result download counts (fused path): the lazy-bundle
        # regression ledger — a HITL-off run must show zero fog_features /
        # fog_scores downloads here
        self.field_downloads: Dict[str, int] = {}
        # --- tenancy (tenancy.py) ------------------------------------------
        # cost_model: per-tenant monetary metering.  Pure accounting — it
        # never moves an event time, so attaching one leaves the schedule
        # bitwise-identical.  fog_queueing (opt-in) folds a stream's real
        # fog-executor queueing delay into its reported latency instead of
        # the pre-tenancy instantaneous-accounting convention.  hitl_cost_s
        # prices HITL collect work on the fog node's *background* lane
        # (Executor priority="background"), where it can never head-of-line
        # block the stream's own serving work.
        self.cost_model = cost_model
        if cost_model is not None:
            self.router.cost_model = cost_model
            cost_model.observe_pool(0.0, self.router.healthy_count())
        self.fog_queueing = fog_queueing
        self.hitl_cost_s = hitl_cost_s
        # --- warm-pool management plane (autoscaler.WarmPoolPolicy) --------
        # every arrival feeds the policy's per-tenant forecasters; the
        # policy schedules "warm" check events (shed after a burst drains,
        # prewarm ahead of the next predicted burst) so cold starts land
        # off the critical path.  None, or an attached-but-disabled policy,
        # schedules nothing — the event timeline stays bitwise-identical
        # to the policy-free scheduler (bench_coldstart gates this at 1
        # and K shards).  Sharded runs share ONE policy instance (like the
        # router); warm_stats is per-shard and sums in the merged report.
        self.warm_pool = warm_pool
        self.warm_stats = {"prewarm_events": 0, "replicas_prewarmed": 0,
                           "shed_events": 0, "spinup_replica_s": 0.0}
        # custom-pipeline dispatch ledger, kept apart from hot_path_stats so
        # tenant flushes never skew host-syncs-per-flush style ratios
        self.tenant_stats = {"flushes": 0, "chunks": 0, "frames": 0}

    # ------------------------------------------------------------------
    def add_stream(self, name: str, *, W, learner=None, annotator=None,
                   slo: Optional[float] = None,
                   weight: float = 1.0, tenant=None) -> StreamState:
        fog_exec = Executor(f"fog-{name}", self.graph.registry,
                            self.graph.protocol.fog)
        lo, hi = self.margin_bounds
        att0 = 1.0 - (min(max(self.slo_margin, lo), hi) - lo) / max(hi - lo,
                                                                    1e-9)
        st = StreamState(name=name, W=np.asarray(W), fog_exec=fog_exec,
                         learner=learner,
                         annotator=annotator or OracleAnnotator(),
                         slo=slo, weight=weight, tenant=tenant,
                         slo_margin=self.slo_margin, att_ewma=att0)
        self.streams[name] = st
        if self.cost_model is not None and tenant is not None:
            self.cost_model.register(tenant)
        return st

    def _tenant_name(self, stream: StreamState) -> str:
        return stream.tenant.name if stream.tenant is not None else "default"

    def submit(self, stream: StreamState, chunk, *, learn: bool = True
               ) -> None:
        stream.pending.append((chunk, learn))
        self._pull_next(stream)

    def _pull_next(self, stream: StreamState) -> None:
        if stream.busy or not stream.pending:
            return
        chunk, learn = stream.pending.popleft()
        stream.busy = True
        # sharded mode: the next ingest belongs on the owner shard's event
        # loop even when this finalize ran on a stealing shard
        owner = stream.owner if stream.owner is not None else self
        owner._push(stream.clock, "ingest",
                    dict(stream=stream, chunk=chunk, learn=learn))

    def _push(self, t: float, action: str, data: dict) -> None:
        heapq.heappush(self._events, (t, next(self._seq), action, data))

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self._events) or len(self.batcher) > 0

    def _peek_key(self) -> Optional[Tuple[float, int]]:
        """(t, seq) of this scheduler's next event, or None when idle.

        The stranded-request safety net (requests queued but no event —
        guards any residual deadline arithmetic slip) surfaces as a
        max-seq key at the batcher's deadline, so a merged multi-shard
        loop orders it after every real event at that time."""
        if self._events:
            ev = self._events[0]
            return (ev[0], ev[1])
        if len(self.batcher):
            nd = self.batcher.next_deadline()
            return (nd if nd is not None else 0.0, sys.maxsize)
        return None

    def step(self) -> bool:
        """Process ONE event (or the safety net); False when fully idle.

        ``run_until_idle`` is ``while step()`` — the ShardedScheduler
        interleaves steps of K of these loops on a merged timeline."""
        if not self._events:
            if not len(self.batcher):
                return False
            w0 = time.perf_counter()
            # safety net: no event left but requests still queued — a
            # stranded request must never be silently dropped
            t = self.batcher.next_deadline()
            self._run_batch(t, self.batcher.take(t))
            self.sched_stats["events"] += 1
            self.sched_stats["step_wall_s"] += time.perf_counter() - w0
            return True
        w0 = time.perf_counter()
        t, _, action, data = heapq.heappop(self._events)
        if action == "ingest":
            self._ingest(t, **data)
        elif action == "arrive":
            self._arrive(t, **data)
        elif action == "flush":
            self._flush(t)
        elif action == "probe":
            self._probe(t, **data)
        elif action == "warm":
            self._warm_check(t)
        else:
            self._finalize(t, data)
        self.sched_stats["events"] += 1
        self.sched_stats["step_wall_s"] += time.perf_counter() - w0
        return True

    def run_until_idle(self) -> None:
        """Drain the event queue (all submitted chunks reach finalize)."""
        while self.step():
            pass

    def drain(self) -> None:
        """Run to idle and assert the claim-check plane leaked nothing.

        Every terminal path — normal dispatch, replica-failure requeue,
        fog fallback, tenant pipelines — must have released its claims by
        the time the event loop empties; a nonzero refcount here is a
        leak, not a pending consumer."""
        self.run_until_idle()
        if self.store is not None:
            leaked = self.store.live_refs()
            if leaked:
                raise AssertionError(
                    f"claim-check leak: {len(leaked)} artifact(s) still "
                    f"referenced at drain: {leaked}")

    # ------------------------------------------------------------------
    def _ingest(self, t: float, stream: StreamState, chunk,
                learn: bool) -> None:
        mode = "cloud"
        if self.fault is not None:
            mode = self.fault.heartbeat(t)
        if mode != "cloud":
            res = self.fallback_fn(chunk.frames)
            self._push(t + res.latency.total, "finalize",
                       dict(stream=stream, chunk=chunk, res=res, mode=mode,
                            learn=learn, t0=t))
            return

        proto = self.graph.protocol
        f = chunk.frames.shape[0]
        qc = proto.fog.encode_time(f)
        enc, _ = stream.fog_exec.run(STAGE_ENCODE, chunk.frames, now=t,
                                     model_time=qc)
        self._push(t, "arrive", dict(stream=stream, chunk=chunk,
                                     learn=learn, enc=enc, qc=qc))

    def _arrive(self, t: float, stream: StreamState, chunk, learn: bool,
                enc, qc: float) -> None:
        """Arrival bookkeeping, split from ingest by a same-sim-time event:
        when several streams ingest in one burst (start-up, post-flush),
        every encode dispatches to the device *before* the first byte-count
        read blocks on one of them, so the host's nbytes reads overlap the
        other chunks' in-flight encodes instead of serializing them.  Same
        simulated times and ordering (same-time events pop in push order);
        ``float(enc.nbytes)`` stays the one unavoidable ingest-side read."""
        wan_bytes = float(enc.nbytes)
        wan_up = self.network.wan_time(wan_bytes, t=t)
        arrival = t + qc + wan_up
        frames = (enc.frames if self.hot_path == "fused"
                  else np.asarray(enc.frames))
        if self.store is not None:
            # claim-check publish: the encoded frames enter the artifact
            # store once (content-addressed — a pooled chunk re-published
            # by any stream dedups to one payload) and the batcher queue
            # entry carries only the reference; _dispatch resolves it at
            # flush-assembly time and releases the claim after dispatch
            frames = self.store.put(frames, key=self._artifact_key(chunk),
                                    now=t)
        req = DetectRequest(
            frames=frames, arrival=arrival, stream=stream,
            weight=stream.weight,
            meta=dict(chunk=chunk, learn=learn, t0=t, qc=qc, wan_up=wan_up,
                      wan_bytes=wan_bytes))
        if stream.slo is not None and self.deadline_batching:
            req.deadline = (t + stream.slo * (1.0 - stream.slo_margin)
                            - self._downstream_est)
        self.batcher.submit(req)
        self._push(arrival, "flush", {})
        nd = self.batcher.next_deadline()
        if nd is not None and nd > arrival + 1e-12:
            self._push(nd, "flush", {})
        if self.warm_pool is not None:
            # feed the per-tenant arrival forecaster and (when the policy
            # is enabled) keep a warm-pool check event scheduled; a
            # disabled policy observes but never schedules, leaving the
            # event timeline untouched
            self.warm_pool.observe(t, chunk.frames.shape[0],
                                   self._tenant_name(stream))
            self._schedule_warm_check(t)

    def _artifact_key(self, chunk) -> str:
        """Content address of a chunk's encoded payload.

        Digest of the *source* HQ host bytes plus the encode parameters
        (hashing the encoded device array would cost a device->host sync).
        Encoding is deterministic, so equal keys imply bitwise-equal
        payloads and dedup is safe.  Memoized on the chunk object; the
        cached key is salt-checked so one chunk shared across schedulers
        with different encode configs never aliases."""
        pcfg = self.graph.protocol.pcfg
        salt = (f"{pcfg.r_low}:{pcfg.q_low}:{int(pcfg.inter_coding)}:"
                f"{self.hot_path}")
        cached = getattr(chunk, "_artifact_key", None)
        if cached is not None and cached[0] == salt:
            return cached[1]
        key = content_key(np.asarray(chunk.frames), salt)
        try:
            chunk._artifact_key = (salt, key)
        except (AttributeError, TypeError):
            pass                        # unmemoizable chunk type: rehash
        return key

    def _flush(self, t: float) -> None:
        while self.batcher.ready(t):
            self._run_batch(t, self.batcher.take(t))
        if len(self.batcher):
            # deadline-driven flushes move earlier as the queue grows (the
            # estimated service time rises); keep an event at the horizon
            nd = self.batcher.next_deadline()
            if nd is not None and nd > t + 1e-12:
                self._push(nd, "flush", {})

    # ------------------------------------------------------------------
    def _run_batch(self, t: float, reqs: List[DetectRequest]) -> None:
        """Shard one flush across healthy replicas and dispatch each shard.

        With one replica (or one request) the flush runs as a single batch —
        the bit-identical single-stream path.  With R healthy replicas the
        chunks are partitioned into ≤R frame-balanced sub-batches, each
        routed to its own replica, so they run concurrently on the
        simulated clock (the cloud ML server's load-balanced replica pool)."""
        if not reqs:
            return
        if any(r.stream.tenant is not None
               and r.stream.tenant.pipeline is not None for r in reqs):
            # multi-tenant flush: the batcher already decided cross-tenant
            # WFQ order, so partitioning by pipeline here preserves each
            # tenant's fair share; custom pipelines dispatch through their
            # own cloud/fog stages on the SAME replica pool + fog executors
            default_reqs: List[DetectRequest] = []
            by_pipe: Dict[str, Tuple[Any, List[DetectRequest]]] = {}
            for r in reqs:
                pipe = (r.stream.tenant.pipeline
                        if r.stream.tenant is not None else None)
                if pipe is None:
                    default_reqs.append(r)
                else:
                    by_pipe.setdefault(pipe.name, (pipe, []))[1].append(r)
            pipe_groups = list(by_pipe.values())
            for gi, (pipe, group) in enumerate(pipe_groups):
                try:
                    self._dispatch_tenant(t, group, pipe)
                except Exception:
                    self._release_claims(
                        [r for _, g in pipe_groups[gi + 1:] for r in g]
                        + default_reqs, t)
                    raise
            reqs = default_reqs
            if not reqs:
                return
        k = min(self.router.healthy_count(), len(reqs))
        if k <= 1:
            groups = [reqs]
        else:
            groups = [[] for _ in range(k)]
            loads = [0] * k
            for r in reqs:            # greedy, preserves WFQ order in-group
                j = min(range(k), key=lambda i: (loads[i], i))
                groups[j].append(r)
                loads[j] += r.frames.shape[0]
        for gi, g in enumerate(groups):
            try:
                self._dispatch(t, g)
            except Exception:
                # terminal abort: sibling sub-batches of this flush were
                # already popped from the batcher, so their claims die
                # with it (drain() asserts refcounts return to zero)
                self._release_claims([r for g2 in groups[gi + 1:]
                                      for r in g2], t)
                raise

    def _release_claims(self, reqs: List[DetectRequest], t: float) -> None:
        if self.store is None:
            return
        for r in reqs:
            if isinstance(r.frames, ClaimCheck):
                self.store.release(r.frames, now=t)

    def _fallback_batch(self, t: float, reqs: List[DetectRequest]) -> None:
        """No healthy replica survives: run each chunk on the fog detector."""
        if self.fallback_fn is None:
            # terminal path: the flush dies here, so its claims must not
            # outlive it (drain() asserts refcounts return to zero)
            if self.store is not None:
                for req in reqs:
                    if isinstance(req.frames, ClaimCheck):
                        self.store.release(req.frames, now=t)
            raise RuntimeError("no healthy replicas and no fog fallback")
        for req in reqs:
            if self.store is not None and isinstance(req.frames, ClaimCheck):
                self.store.release(req.frames, now=t)
            chunk = req.meta["chunk"]
            res = self.fallback_fn(chunk.frames)
            self._push(t + res.latency.total, "finalize",
                       dict(stream=req.stream, chunk=chunk, res=res,
                            mode="fog-fallback", learn=req.meta["learn"],
                            t0=req.meta["t0"]))

    def _dispatch(self, t: float, reqs: List[DetectRequest]) -> None:
        proto = self.graph.protocol
        m0 = time.perf_counter()
        # artifact-corruption faults fire at flush assembly: flip stored
        # payload bytes now, so the integrity-checked resolve below detects
        # and repairs every one of them before it can reach the detector
        if self.store is not None and self.fault is not None:
            due_fn = getattr(self.fault, "due_corruptions", None)
            if due_fn is not None:
                keys, seen = [], set()
                for r in reqs:
                    if (isinstance(r.frames, ClaimCheck)
                            and r.frames.key not in seen):
                        seen.add(r.frames.key)
                        keys.append(r.frames.key)
                for i in range(due_fn(t, len(keys))):
                    self.store.corrupt(keys[i])
        # pick a replica; health-check it against the fault schedule first
        # (the schedule is keyed by the replica's stable uid, not its pool
        # position — positions shift when the autoscaler resizes the pool)
        while True:
            idx = self.router.pick()
            if idx is None:
                self._fallback_batch(t, reqs)
                return
            uid = self.router.replicas[idx].uid
            if self.fault is not None and self.fault.replica_down(uid, t):
                self.router.mark_unhealthy(idx, now=t)
                self.fault.note_replica_failure(uid, t, requeued=0)
                self._schedule_probe(uid, t)
                continue
            break
        fused = self.hot_path == "fused"
        # claim-check resolve: flush assembly is the ONE place payloads are
        # pulled from the store.  A single-request flush passes the stored
        # array object straight through pack_frames_device, preserving the
        # zero-copy identity shortcut.
        if self.store is not None:
            payloads = [self._resolve_payload(r, t) for r in reqs]
        else:
            payloads = [r.frames for r in reqs]
        if fused:
            batch, slices, pad = pack_frames_device(
                payloads, buckets=self.batcher.pad_buckets)
        else:
            batch, slices, pad = pack_frames(
                [np.asarray(p) for p in payloads],
                buckets=self.batcher.pad_buckets)
        n_frames = batch.shape[0]
        svc = proto.cloud.detect_time(n_frames)
        rep = self.router.replicas[idx]
        est_start = max(t, min(rep.executor.busy_until))
        if self.fault is not None:
            # straggler windows stretch the true service time; flap/death
            # windows interrupt it.  Both are keyed on where the service
            # actually sits on the replica's device horizon, not on `t`.
            mult = self.fault.service_multiplier(uid, est_start)
            svc_eff = svc * mult if mult != 1.0 else svc
            fail_t = self.fault.fail_time_in(uid, est_start,
                                             est_start + svc_eff)
        else:
            svc_eff, fail_t = svc, None
        if fail_t is not None:
            # the replica dies (or flaps out) while this sub-batch is in
            # service: its work is lost, the outage is detected at the
            # failure time, and the chunks re-queue to surviving replicas
            # (arrival and fair-queueing position preserved — nothing is
            # dropped).  Their claims were not released, so the re-flush
            # resolves the same stored payloads again.  A transient flap
            # additionally starts a health-probe chain so the replica
            # re-admits once its window closes.
            self.router.mark_unhealthy(idx, now=fail_t)
            self.fault.note_replica_failure(uid, fail_t,
                                            requeued=len(reqs))
            self.chaos_stats["requeues"] += len(reqs)
            self._schedule_probe(uid, fail_t)
            for r in reqs:
                r.not_before = fail_t
                r.retries += 1
                self.batcher.submit(r)
            self._push(fail_t, "flush", {})
            return
        if self.store is not None:
            # dispatch is committed: the batch owns the frame data now, so
            # the claims drop and idle payloads age toward TTL eviction
            for r in reqs:
                self.store.release(r.frames, now=t)
            self.store.sweep(t)
        # real queue depth (frames still waiting / in flight to the cloud)
        queue_depth = self.batcher.pending_frames
        if self.cost_model is not None:
            self.cost_model.observe_pool(t, self.router.healthy_count())
        # per-dispatch timeout = the flush's SLO slack (tightest pending
        # detect deadline), and the hedge decision: a primary whose
        # service-rate EWMA says this sub-batch will both straggle (beyond
        # the slack threshold) and miss that deadline gets a speculative
        # duplicate on the best alternate replica, first-result-wins
        deadline = min((r.deadline for r in reqs if r.deadline is not None),
                       default=None)
        timeout = max(0.0, deadline - t) if deadline is not None else None
        hedge = None
        if (self.hedging and self.fault is not None
                and deadline is not None and rep.rate_ewma is not None):
            est_svc = rep.rate_ewma * n_frames
            if (est_svc > svc * (1.0 + self.hedge_slack)
                    and est_start + est_svc > deadline):
                hedge = self._pick_hedge(t, idx, svc, n_frames,
                                         est_start + est_svc)
        self.hot_path_stats["flushes"] += 1
        if fused:
            self._dispatch_fused(t, reqs, slices, pad, batch, svc_eff, idx,
                                 queue_depth, timeout, hedge)
        else:
            self._dispatch_sync(t, reqs, slices, pad, batch, svc_eff, idx,
                                queue_depth, timeout, hedge)
        # observed per-frame service rate feeds the next hedge decision;
        # one-dispatch lag is the realistic detector dynamic (a straggler
        # is spotted by its first slow completion, then hedged around)
        obs = svc_eff / max(n_frames, 1)
        rep.rate_ewma = (obs if rep.rate_ewma is None
                         else 0.5 * rep.rate_ewma + 0.5 * obs)
        self.sched_stats["model_wall_s"] += time.perf_counter() - m0

    def _resolve_payload(self, req: DetectRequest, t: float):
        """Resolve one request's claim; repair a corrupted payload.

        The store's content hash catches flipped bytes at flush assembly;
        encoding is deterministic, so re-deriving from the source chunk
        reconstructs the original payload bitwise (a forced re-put) and
        the flush proceeds with zero garbage served.  The repair costs no
        simulated time: it models the fog tier re-sending a chunk that is
        still in its local buffer, which is dwarfed by the detect service
        time already on the clock."""
        try:
            return self.store.get(req.frames)
        except ArtifactCorrupted:
            enc = self.graph._encode(req.meta["chunk"].frames)
            fresh = (enc.frames if self.hot_path == "fused"
                     else np.asarray(enc.frames))
            self.store.repair(req.frames.key, fresh)
            self.chaos_stats["corruptions_repaired"] += 1
            self.monitor.log_event("artifact_repair", t=t,
                                   key=req.frames.key)
            return self.store.get(req.frames)

    def _pick_hedge(self, t: float, primary: int, svc: float,
                    n_frames: int, primary_est_done: float
                    ) -> Optional[Tuple[int, float]]:
        """Best alternate replica for a speculative duplicate, or None.

        Deterministic: candidates are scored by estimated completion
        (service-rate EWMA; nominal when unobserved) with uid as the
        tie-break, and a candidate must beat the primary's estimate —
        hedging onto an equally-slow pool only burns device time.
        Replicas the fault schedule marks down, known-straggling, or
        dying mid-hedge are skipped (the hedge must *cover* the fault,
        not re-roll it).  Returns ``(pool_index, true_service_time)``."""
        best = None
        for i, r in enumerate(self.router.replicas):
            if i == primary or not r.healthy:
                continue
            uid = r.uid
            if self.fault.replica_down(uid, t):
                continue
            start = max(t, min(r.executor.busy_until))
            mult = self.fault.service_multiplier(uid, start)
            h_svc = svc * mult if mult != 1.0 else svc
            if self.fault.fail_time_in(uid, start, start + h_svc) is not None:
                continue
            est_rate = (r.rate_ewma if r.rate_ewma is not None
                        else svc / max(n_frames, 1))
            if est_rate * n_frames > svc * (1.0 + self.hedge_slack):
                continue                     # known straggler itself
            est_done = start + est_rate * n_frames
            if est_done >= primary_est_done - 1e-12:
                continue                     # no expected win
            if best is None or (est_done, uid) < best[:2]:
                best = (est_done, uid, i, h_svc)
        return None if best is None else (best[2], best[3])

    def _route_detect(self, stage: str, args: tuple, *, t: float,
                      svc: float, idx: int, queue_depth: int,
                      timeout: Optional[float], hedge):
        """Route the detect stage, optionally covered by a hedge.

        The hedge duplicate books real device time on the alternate
        replica (``Router.hedge``) but never re-runs the jit — the
        primary's result is reused bitwise, only the completion-time race
        differs.  The primary wins exact ties, so hedging can only move a
        completion *earlier*.  Returns ``(out, done, svc_winner,
        hedge_billed_svc_or_None)``."""
        out, done, _ = self.router.route(stage, *args, now=t,
                                         model_time=svc,
                                         queue_depth=queue_depth,
                                         replica=idx, timeout=timeout)
        self._detect_windows.append((done - svc, svc))
        h_billed = None
        if hedge is not None:
            h_idx, h_svc = hedge
            h_start, h_done = self.router.hedge(h_idx, now=t,
                                                model_time=h_svc)
            self._detect_windows.append((h_start, h_svc))
            self.chaos_stats["hedges"] += 1
            self.chaos_stats["hedge_busy_s"] += h_svc
            h_billed = h_svc
            self.monitor.log_event("hedge", t=t, primary=idx,
                                   alternate=h_idx, svc=svc,
                                   hedge_svc=h_svc)
            if h_done < done - 1e-12:
                done, svc = h_done, h_svc
                self.chaos_stats["hedge_wins"] += 1
        return out, done, svc, h_billed

    def _schedule_probe(self, uid: int, t: float) -> None:
        """Start a health-probe chain for a transiently-down replica."""
        if self.fault is None or uid in self._probing:
            return
        trans = getattr(self.fault, "transient", None)
        if trans is None or not trans(uid, t):
            return                    # permanent death: probing is wasted
        self._probing.add(uid)
        self._push(t + self.probe_base, "probe",
                   dict(uid=uid, interval=self.probe_base))

    def _probe(self, t: float, uid: int, interval: float) -> None:
        """One health probe: re-admit the replica or back off and retry.

        Backoff doubles up to ``probe_max`` so a long flap costs O(log)
        probe events, not a busy-wait.  In sharded runs several shards may
        run chains for the same uid; ``Router.readmit`` is idempotent and
        the healthy check below retires duplicate chains, so the replica
        re-admits exactly once."""
        self.chaos_stats["probes"] += 1
        idx = next((i for i, r in enumerate(self.router.replicas)
                    if r.uid == uid), None)
        if idx is None or self.router.replicas[idx].healthy:
            self._probing.discard(uid)      # retired, or another shard won
            return
        if self.fault is not None and self.fault.replica_down(uid, t):
            nxt = min(interval * 2.0, self.probe_max)
            self._push(t + nxt, "probe", dict(uid=uid, interval=nxt))
            return
        self._probing.discard(uid)
        if self.router.readmit(idx, now=t):
            self.chaos_stats["readmits"] += 1
            self.monitor.log_event("replica_readmit", t=t, replica=uid)
        if len(self.batcher):
            # backlog that piled up behind the outage flushes immediately
            self._push(t, "flush", {})

    # -- warm-pool plane ------------------------------------------------
    def _schedule_warm_check(self, now: float) -> None:
        """Ask the warm-pool policy when it next wants to act and book a
        ``warm`` event there.  The policy deduplicates (at most one
        outstanding check, bounded fires per observation epoch), so the
        chain self-terminates once traffic stops and ``run_until_idle``
        always drains."""
        pol = self.warm_pool
        if pol is None or not pol.enabled:
            return
        ft = pol.next_check(now)
        if ft is not None:
            self._push(ft, "warm", {})

    def _warm_check(self, t: float) -> None:
        """One warm-pool actuation: prewarm ahead of a forecast burst or
        shed idle keep-alive replicas past the break-even horizon.  Runs
        off the data path — the spin-up happens *before* the burst lands,
        which is the whole point."""
        pol = self.warm_pool
        pol.fired()
        target = pol.target_replicas(t)
        cur = self.router.healthy_count()
        if target > cur:
            self.router.scale_replicas(target, now=t, prewarm=True)
            added = self.router.healthy_count() - cur
            if added > 0:
                self.warm_stats["prewarm_events"] += 1
                self.warm_stats["replicas_prewarmed"] += added
                self.warm_stats["spinup_replica_s"] += (
                    added * self.router.cold_start_s)
                if self.cost_model is not None:
                    self.cost_model.note_prewarm(
                        t, added, self.router.cold_start_s)
        elif target < cur:
            self.router.scale_replicas(target, now=t)
            if self.router.healthy_count() < cur:
                self.warm_stats["shed_events"] += 1
        self._schedule_warm_check(t)

    def _dispatch_sync(self, t: float, reqs: List[DetectRequest], slices,
                       pad: int, batch, svc: float, idx: int,
                       queue_depth: int, timeout: Optional[float] = None,
                       hedge=None) -> None:
        """Pre-fusion baseline: blocking detect, one ``split_uncertain``
        jit call plus two scalar device syncs per chunk, full-budget
        classify, immediate result materialization."""
        proto = self.graph.protocol
        n_frames = batch.shape[0]
        w0 = time.perf_counter()
        det, done, svc_w, h_billed = self._route_detect(
            STAGE_DETECT, (jnp.asarray(batch),), t=t, svc=svc, idx=idx,
            queue_depth=queue_depth, timeout=timeout, hedge=hedge)
        jax.block_until_ready(det)
        self.hot_path_stats["host_syncs"] += 1
        self.detect_stats["calls"] += 1
        self.detect_stats["frames"] += n_frames - pad
        self.detect_stats["padded_frames"] += pad
        self.detect_stats["wall_s"] += time.perf_counter() - w0
        start = done - svc_w

        for req, sl in zip(reqs, slices):
            det_i = {k: v[sl] for k, v in det.items()}
            pcfg_req = proto.pcfg
            if (req.stream.theta_cls is not None
                    or req.stream.theta_loc is not None):
                # per-site thresholds: a frozen-config replace stays
                # hashable, so the handful of distinct per-site configs
                # each compile split_uncertain once
                pcfg_req = dataclasses.replace(
                    pcfg_req,
                    theta_cls=(req.stream.theta_cls
                               if req.stream.theta_cls is not None
                               else pcfg_req.theta_cls),
                    theta_loc=(req.stream.theta_loc
                               if req.stream.theta_loc is not None
                               else pcfg_req.theta_loc))
            split, coord_bytes = protocol_mod.split_uncertain(pcfg_req,
                                                              det_i)
            wan_down = self.network.wan_time(float(coord_bytes), t=done)
            n_crops = int(np.sum(np.asarray(split.prop_valid)))
            self.hot_path_stats["host_syncs"] += 2   # the two scalar reads
            clf_time = proto.fog.classify_time(max(n_crops, 1))
            obs = wan_down + clf_time
            self._downstream_est = (obs if obs > self._downstream_est
                                    else 0.9 * self._downstream_est
                                    + 0.1 * obs)
            stream = req.stream
            chunk = req.meta["chunk"]
            self.hot_path_stats["crops_classified"] += split.prop_valid.size
            self.hot_path_stats["crops_budget"] += split.prop_valid.size
            if stream.ensemble is not None:
                snaps_dev, omega_dev = stream.ensemble_device()
                merged, done_c = stream.fog_exec.run(
                    STAGE_CLASSIFY_ENS, jnp.asarray(chunk.frames), split,
                    snaps_dev, omega_dev, now=done + wan_down,
                    model_time=clf_time)
            else:
                merged, done_c = stream.fog_exec.run(
                    STAGE_CLASSIFY, jnp.asarray(chunk.frames), split,
                    jnp.asarray(stream.W), now=done + wan_down,
                    model_time=clf_time)
            # fog_queueing: the wait for the stream's fog device (busy with
            # an earlier chunk) joins the reported latency; default keeps
            # the pre-tenancy instantaneous-accounting convention
            fog_wait = (max(0.0, done_c - clf_time - (done + wan_down))
                        if self.fog_queueing else 0.0)
            if self.cost_model is not None:
                f = req.frames.shape[0]
                tname = self._tenant_name(stream)
                self.cost_model.charge_cloud(
                    tname, frames=f, invocations=f,
                    busy_s=svc * f / max(n_frames - pad, 1), t=t)
                if h_billed is not None:
                    # a hedge is a real invocation: its duplicate device
                    # time lands in the tenant's ledger either way the
                    # race resolves
                    self.cost_model.charge_hedge(
                        tname, invocations=f,
                        busy_s=h_billed * f / max(n_frames - pad, 1), t=t)
                self.cost_model.charge_fog(tname, clf_time, t)
            lat = LatencyBreakdown(
                quality_control=req.meta["qc"],
                transmission=req.meta["wan_up"] + wan_down,
                cloud_inference=svc_w,
                fog_inference=clf_time,
                queue_wait=max(0.0, start - req.arrival) + fog_wait)
            res = protocol_mod.assemble_result(
                split, merged, wan_bytes=req.meta["wan_bytes"],
                coord_bytes=float(coord_bytes),
                cloud_frames=req.frames.shape[0], latency=lat)
            self.hot_path_stats["host_syncs"] += 1   # eager materialization
            self._push(req.meta["t0"] + lat.total, "finalize",
                       dict(stream=stream, chunk=chunk, res=res,
                            mode="cloud", learn=req.meta["learn"],
                            t0=req.meta["t0"]))

    def _dispatch_fused(self, t: float, reqs: List[DetectRequest], slices,
                        pad: int, batch, svc: float, idx: int,
                        queue_depth: int, timeout: Optional[float] = None,
                        hedge=None) -> None:
        """Device-resident hot path: one fused detect+split dispatch, ONE
        blocking host read (the validity mask) per flush, one compacted
        cross-stream classify dispatch, and per-chunk results left as
        device futures drained at their finalize events."""
        proto = self.graph.protocol
        n_frames = batch.shape[0]
        w0 = time.perf_counter()
        dyn = any(r.stream.theta_cls is not None
                  or r.stream.theta_loc is not None for r in reqs)
        if dyn:
            # per-site thresholds in play: per-frame theta vectors ride
            # into the dynamic fused stage as traced args (thetas only
            # enter elementwise comparisons, so tracing them is exact);
            # detector pad rows keep the global defaults
            tc = np.full(n_frames, proto.pcfg.theta_cls, np.float32)
            tl = np.full(n_frames, proto.pcfg.theta_loc, np.float32)
            for r, sl in zip(reqs, slices):
                if r.stream.theta_cls is not None:
                    tc[sl] = r.stream.theta_cls
                if r.stream.theta_loc is not None:
                    tl[sl] = r.stream.theta_loc
            split, done, svc_w, h_billed = self._route_detect(
                STAGE_DETECT_SPLIT_DYN,
                (batch, jnp.asarray(tc), jnp.asarray(tl)), t=t, svc=svc,
                idx=idx, queue_depth=queue_depth, timeout=timeout,
                hedge=hedge)
        else:
            # donate the packed batch only when it is the dispatch-owned
            # multi-request concat; a single-request flush passes the
            # encode-output / store-held array through untouched
            stage = (STAGE_DETECT_SPLIT_DON
                     if self.donate_detect and len(reqs) > 1
                     else STAGE_DETECT_SPLIT)
            split, done, svc_w, h_billed = self._route_detect(
                stage, (batch,), t=t, svc=svc, idx=idx,
                queue_depth=queue_depth, timeout=timeout, hedge=hedge)
        # THE flush's single blocking device->host read: per-chunk coord
        # bytes, crop counts, and the compaction gather plan are all
        # derived from this one (F, N) bool mask on the host
        pv = np.asarray(split.prop_valid)
        self.hot_path_stats["host_syncs"] += 1
        self.detect_stats["calls"] += 1
        self.detect_stats["frames"] += n_frames - pad
        self.detect_stats["padded_frames"] += pad
        self.detect_stats["wall_s"] += time.perf_counter() - w0
        start = done - svc_w

        # detector padding rows carry no chunk: drop them before building
        # the gather plan (a zero-frame can still excite a random detector)
        f_real = n_frames - pad
        pv = pv[:f_real]
        counts = pv.sum(axis=1)
        split_real = (reg.RegionSplit(*(v[:f_real] for v in split))
                      if pad else split)
        fidx, ridx, n_valid, bucket = reg.compaction_indices(
            pv, self.crop_buckets)
        self.hot_path_stats["crops_classified"] += bucket
        self.hot_path_stats["crops_budget"] += int(pv.size)

        # pack the cached HQ frames: host-side video sources, so concat on
        # the host and pay ONE upload per flush (not one device_put per
        # chunk), and stack the distinct per-stream readouts
        if len(reqs) == 1:
            hq_batch = jnp.asarray(reqs[0].meta["chunk"].frames)
        else:
            hq_batch = jnp.asarray(np.concatenate(
                [np.asarray(r.meta["chunk"].frames) for r in reqs], axis=0))
        w_group: Dict[int, int] = {}
        group_streams: List[StreamState] = []
        req_w = np.empty(len(reqs), np.int32)
        frame_req = np.empty(f_real, np.int32)
        use_ens = any(r.stream.snaps is not None for r in reqs)
        for qi, (r, sl) in enumerate(zip(reqs, slices)):
            key = (id(r.stream.snaps) if r.stream.snaps is not None
                   else id(r.stream.W))
            if key not in w_group:
                w_group[key] = len(group_streams)
                group_streams.append(r.stream)
            req_w[qi] = w_group[key]
            frame_req[sl] = qi
        # one (3, B) index upload: (fidx, ridx, widx) rows
        idxs = np.zeros((3, bucket), np.int32)
        idxs[0] = fidx
        idxs[1] = ridx
        if n_valid:
            idxs[2, :n_valid] = req_w[frame_req[fidx[:n_valid]]]

        clf_time = proto.fog.classify_time(max(n_valid, 1))
        if use_ens:
            # Eq. 9 ensemble serving: widx picks a per-stream snapshot
            # lineage; plain single-readout streams ride along as the
            # zero-padded degenerate lineage [W] / omega=[1.0] (bitwise-
            # identical scores, see classify_compacted_ensemble)
            snaps_dev, omegas_dev = self._ensemble_stack(group_streams)
            self.hot_path_stats["ensemble_flushes"] += 1
            merged, _ = self.fog_batch_exec.run(
                STAGE_CLASSIFY_ENS_BATCH, hq_batch, split_real, snaps_dev,
                omegas_dev, jnp.asarray(idxs), now=done,
                model_time=clf_time)
        else:
            ws_list = [s.W_device() for s in group_streams]
            Ws = (ws_list[0][None] if len(ws_list) == 1
                  else jnp.stack(ws_list))
            merged, _ = self.fog_batch_exec.run(
                STAGE_CLASSIFY_BATCH, hq_batch, split_real, Ws,
                jnp.asarray(idxs), now=done, model_time=clf_time)

        # the whole flush's results travel as ONE device-side bundle whose
        # fields materialize lazily: a consumer's first touch of a field
        # downloads that buffer once for the whole flush and every chunk
        # slices numpy views — fields nothing reads are never downloaded
        bundle = _FlushBundle(split_real, merged, self.hot_path_stats,
                              self.field_downloads)
        bundle.pending = len(reqs)
        self._bundles.append(bundle)
        hps = self.hot_path_stats
        hps["bundle_bytes"] += bundle.device_bytes
        hps["bundle_bytes_peak"] = max(hps["bundle_bytes_peak"],
                                       hps["bundle_bytes"])
        hps["bundles_retained_peak"] = max(hps["bundles_retained_peak"],
                                           len(self._bundles))
        # residency time series (sim clock): the steady-state bench asserts
        # this stays flat under bounded retention
        self.monitor.record("bundle_bytes", float(hps["bundle_bytes"]), t)
        for req, sl in zip(reqs, slices):
            n_crops = int(counts[sl].sum())
            coord_bytes = 9.0 * n_crops
            wan_down = self.network.wan_time(coord_bytes, t=done)
            clf_time = proto.fog.classify_time(max(n_crops, 1))
            obs = wan_down + clf_time
            self._downstream_est = (obs if obs > self._downstream_est
                                    else 0.9 * self._downstream_est
                                    + 0.1 * obs)
            stream = req.stream
            chunk = req.meta["chunk"]
            # the stream's share of the batched classify: pure accounting
            # on its own fog node's clock (the compute already ran batched)
            _, done_c = stream.fog_exec.run(STAGE_CLASSIFY_VIEW, sl,
                                            now=done + wan_down,
                                            model_time=clf_time)
            fog_wait = (max(0.0, done_c - clf_time - (done + wan_down))
                        if self.fog_queueing else 0.0)
            if self.cost_model is not None:
                f = req.frames.shape[0]
                tname = self._tenant_name(stream)
                self.cost_model.charge_cloud(
                    tname, frames=f, invocations=f,
                    busy_s=svc * f / max(f_real, 1), t=t)
                if h_billed is not None:
                    # a hedge is a real invocation: its duplicate device
                    # time lands in the tenant's ledger either way the
                    # race resolves
                    self.cost_model.charge_hedge(
                        tname, invocations=f,
                        busy_s=h_billed * f / max(f_real, 1), t=t)
                self.cost_model.charge_fog(tname, clf_time, t)
            lat = LatencyBreakdown(
                quality_control=req.meta["qc"],
                transmission=req.meta["wan_up"] + wan_down,
                cloud_inference=svc_w,
                fog_inference=clf_time,
                queue_wait=max(0.0, start - req.arrival) + fog_wait)
            res = LazyChunkResult(
                bundle, sl, wan_bytes=req.meta["wan_bytes"],
                coord_bytes=coord_bytes,
                cloud_frames=req.frames.shape[0], latency=lat)
            self._inflight.append(res)
            self.hot_path_stats["inflight_peak"] = max(
                self.hot_path_stats["inflight_peak"], len(self._inflight))
            self._push(req.meta["t0"] + lat.total, "finalize",
                       dict(stream=stream, chunk=chunk, res=res,
                            inflight=True, mode="cloud",
                            learn=req.meta["learn"], t0=req.meta["t0"]))

    def _dispatch_tenant(self, t: float, reqs: List[DetectRequest],
                         pipe) -> None:
        """Dispatch one tenant pipeline's share of a flush: a batched cloud
        stage through the shared replica pool, then each chunk's fog merge
        stage on its stream's own fog executor.

        Mirrors ``_dispatch``'s claim-check discipline (resolve at assembly,
        release at commit) and detect-window accounting, but keeps its
        counters in ``tenant_stats`` so the High-Low hot-path ratios stay
        clean.  Custom pipelines do not participate in the fault-schedule
        fallback (that path re-encodes for the fog *detector*, which a
        non-detection graph doesn't have)."""
        m0 = time.perf_counter()
        idx = self.router.pick()
        if idx is None:
            # terminal path (tenant pipelines have no fog fallback): the
            # claims must not outlive the flush that dies here
            if self.store is not None:
                for r in reqs:
                    if isinstance(r.frames, ClaimCheck):
                        self.store.release(r.frames, now=t)
            raise RuntimeError(
                f"no healthy replicas for tenant pipeline {pipe.name!r}")
        if self.store is not None:
            payloads = [self._resolve_payload(r, t) for r in reqs]
        else:
            payloads = [r.frames for r in reqs]
        batch, slices, pad = pack_frames_device(
            payloads, buckets=self.batcher.pad_buckets)
        if self.store is not None:
            for r in reqs:
                self.store.release(r.frames, now=t)
            self.store.sweep(t)
        n_frames = batch.shape[0]
        f_real = n_frames - pad
        svc = n_frames / pipe.cloud_fps
        queue_depth = self.batcher.pending_frames
        if self.cost_model is not None:
            self.cost_model.observe_pool(t, self.router.healthy_count())
        deadline = min((r.deadline for r in reqs if r.deadline is not None),
                       default=None)
        timeout = max(0.0, deadline - t) if deadline is not None else None
        out, done, _ = self.router.route(
            pipe.cloud_stage, batch, now=t, model_time=svc,
            queue_depth=queue_depth, replica=idx, timeout=timeout)
        start = done - svc
        self._detect_windows.append((start, svc))
        self.tenant_stats["flushes"] += 1
        self.tenant_stats["chunks"] += len(reqs)
        self.tenant_stats["frames"] += f_real

        for req, sl in zip(reqs, slices):
            stream = req.stream
            chunk = req.meta["chunk"]
            f = req.frames.shape[0]
            out_sl = out[sl]
            coord_bytes = float(getattr(out_sl, "nbytes", 8 * f))
            wan_down = self.network.wan_time(coord_bytes, t=done)
            fog_time = f / pipe.fog_fps
            result, done_c = stream.fog_exec.run(
                pipe.fog_stage, chunk.frames, out_sl,
                now=done + wan_down, model_time=fog_time)
            fog_wait = (max(0.0, done_c - fog_time - (done + wan_down))
                        if self.fog_queueing else 0.0)
            lat = LatencyBreakdown(
                quality_control=req.meta["qc"],
                transmission=req.meta["wan_up"] + wan_down,
                cloud_inference=svc,
                fog_inference=fog_time,
                queue_wait=max(0.0, start - req.arrival) + fog_wait)
            billed = pipe.billed(result, f)
            if self.cost_model is not None:
                tname = self._tenant_name(stream)
                self.cost_model.charge_cloud(
                    tname, frames=f, invocations=billed,
                    busy_s=svc * f / max(f_real, 1), t=t)
                self.cost_model.charge_fog(tname, fog_time, t)
            res = TenantChunkResult(
                result, wan_bytes=req.meta["wan_bytes"],
                coord_bytes=coord_bytes + pipe.out_bytes(result, f),
                cloud_frames=billed, latency=lat)
            self._push(req.meta["t0"] + lat.total, "finalize",
                       dict(stream=stream, chunk=chunk, res=res,
                            mode="cloud", learn=req.meta["learn"],
                            t0=req.meta["t0"]))
        self.sched_stats["model_wall_s"] += time.perf_counter() - m0

    def _finalize(self, t: float, data: dict) -> None:
        stream, chunk = data["stream"], data["chunk"]
        res = data["res"]
        self.sched_stats["finalizes"] += 1
        if data.get("inflight"):
            # retire the in-flight future: its arrays stay device-side in
            # the flush bundle until a consumer touches a field, so the
            # device ran ahead on later flushes while this result waited
            # for its event.  Identity scan, not deque.remove: == on lazy
            # results would trigger attribute materialization.
            for i, p in enumerate(self._inflight):
                if p is res:
                    del self._inflight[i]
                    break
        t0 = data["t0"]
        self.monitor.record("latency", res.latency.total, t0)
        self.monitor.record("wan_bytes", res.wan_bytes, t0)
        self.monitor.incr("cloud_frames", res.cloud_frames)
        tenant_tagged = stream.tenant is not None or self.cost_model is not None
        if tenant_tagged:
            # per-tenant attribution: tagged latency/attainment series feed
            # throughput_report()["tenants"] and the noisy-neighbor gate
            tname = self._tenant_name(stream)
            self.monitor.record(f"latency:{tname}", res.latency.total, t0)
        if self.cost_model is not None:
            tname = self._tenant_name(stream)
            self.cost_model.charge_egress(
                tname, res.wan_bytes + res.coord_bytes, t0)
            self.cost_model.note_chunk(tname)
        if stream.slo is not None:
            met = res.latency.total <= stream.slo + 1e-9
            self.monitor.record("slo_attained", 1.0 if met else 0.0, t0)
            if tenant_tagged:
                self.monitor.record(f"slo_attained:{self._tenant_name(stream)}",
                                    1.0 if met else 0.0, t0)
            self.monitor.record("slo_margin",
                                stream.slo - res.latency.total, t0)
            if self.adaptive_margin:
                a = self.margin_alpha
                stream.att_ewma = ((1.0 - a) * stream.att_ewma
                                   + a * (1.0 if met else 0.0))
                lo, hi = self.margin_bounds
                stream.slo_margin = lo + (hi - lo) * (1.0 - stream.att_ewma)
        if (self.plane is None and data["learn"]
                and stream.learner is not None
                and data["mode"] == "cloud"
                and not stream.learner.budget_exhausted):
            # HITL feedback runs on the fog node's BACKGROUND lane: the
            # stream's next chunk is never head-of-line blocked behind
            # collect work (the PR-2 follow-up), and a nonzero hitl_cost_s
            # prices the labeling/update time into the tenant's fog spend
            # without touching any serving-path completion time
            updated, done_c = stream.fog_exec.run(
                STAGE_COLLECT, stream, chunk, res, now=t,
                model_time=self.hitl_cost_s, priority="background")
            if self.cost_model is not None and self.hitl_cost_s > 0:
                self.cost_model.charge_fog(self._tenant_name(stream),
                                           self.hitl_cost_s, done_c)
            if updated:
                self.monitor.incr("model_updates")
        stream.clock = t
        stream.results.append((chunk, res, data["mode"]))
        stream.busy = False
        if self.plane is not None and data["learn"]:
            # the continual-learning plane runs beside serving: labeling and
            # training cost background time, never this chunk's latency
            self.plane.on_chunk(self, stream, chunk, res, t, data["mode"])
        if data.get("inflight"):
            # last: every consumer that runs *at* finalize (HITL collect,
            # the learning plane) has touched its fields by now
            res._bundle.pending -= 1
            self._maybe_seal()
        self._pull_next(stream)

    def _maybe_seal(self) -> None:
        """Seal oldest fully-finalized bundles past the retention cap."""
        cap = self.max_retained_bundles
        if cap is None:
            return
        hps = self.hot_path_stats
        while len(self._bundles) > cap and self._bundles[0].pending == 0:
            b = self._bundles.popleft()
            hps["bundle_bytes"] -= b.device_bytes
            b.seal()
            hps["bundles_sealed"] += 1

    # ------------------------------------------------------------------
    def _ensemble_stack(self, group_streams: List[StreamState]):
        """Stacked (G, T, d+1, C) snapshot lineages + (G, T) omegas for one
        flush's readout groups, zero-padded to the flush's longest lineage.

        Memoized on the source arrays' identities: a steady flush mix
        uploads the stack once; a hot-swap (new W / new ensemble object on
        any stream) misses and rebuilds.  The cache holds strong references
        to the sources so an id can never be recycled under the key."""
        srcs = [(s.snaps if s.snaps is not None else s.W)
                for s in group_streams]
        key = tuple(id(s) for s in srcs)
        hit = self._ens_cache.get(key)
        if hit is not None:
            return hit[1]
        lineages = []
        for s in group_streams:
            if s.snaps is not None:
                lineages.append((np.asarray(s.snaps, np.float32),
                                 np.asarray(s.omega, np.float32)))
            else:
                W = np.asarray(s.W, np.float32)
                lineages.append((W[None], np.ones(1, np.float32)))
        t_max = max(sn.shape[0] for sn, _ in lineages)
        d, c = lineages[0][0].shape[1:]
        snaps = np.zeros((len(lineages), t_max, d, c), np.float32)
        omegas = np.zeros((len(lineages), t_max), np.float32)
        for gi, (sn, om) in enumerate(lineages):
            snaps[gi, : sn.shape[0]] = sn
            omegas[gi, : om.shape[0]] = om
        out = (jnp.asarray(snaps), jnp.asarray(omegas))
        self._ens_cache[key] = (srcs, out)
        while len(self._ens_cache) > self._ens_cache_cap:
            self._ens_cache.pop(next(iter(self._ens_cache)))
        # upload-regression ledger for the fused path: recurring flush
        # mixes should hit the memo — a climbing count means cache thrash
        self.hot_path_stats["ensemble_uploads"] += 1
        return out

    # ------------------------------------------------------------------
    def _swap_targets(self, stream: Optional[str]) -> List[StreamState]:
        if stream is None:
            return list(self.streams.values())
        return [self.streams[stream]]

    def hot_swap(self, W, *, version=None, t: Optional[float] = None,
                 stream: Optional[str] = None) -> int:
        """Swap a new fog-classifier readout into live streams' classify
        stage, mid-run and without stalling.

        ``stream`` names a single camera to swap (per-site promotion: a
        drift episode in camera k must touch only camera k's readout);
        ``None`` keeps the original swap-everywhere behaviour.  Chunks
        whose classify stage already dispatched finish on the old weights;
        everything dispatched after this call uses the new ones — no chunk
        is dropped, duplicated, or delayed by the swap.  A readout swap
        supersedes any Eq. 9 ensemble the target stream was serving.
        Returns the number of in-flight chunks the swap left untouched."""
        W = np.asarray(W)
        targets = self._swap_targets(stream)
        inflight = sum(1 for s in targets if s.busy)
        for s in targets:
            s.W = W.copy()             # per-stream cache refresh
            s.clear_ensemble()
        self.monitor.incr("hot_swaps")
        self.monitor.log_event("hot_swap", t=t if t is not None else 0.0,
                               version=version, inflight=inflight,
                               stream=stream)
        return inflight

    def set_stream_thresholds(self, stream: str, *,
                              theta_cls: Optional[float] = None,
                              theta_loc: Optional[float] = None,
                              t: Optional[float] = None) -> None:
        """Override one stream's detector split thresholds mid-run.

        ``None`` restores the global :class:`ProtocolConfig` default for
        that threshold (the bit-compatible state).  Chunks already past
        their detect dispatch keep the thresholds they ran with; the next
        flush containing this stream routes through the dynamic fused
        stage (or a per-site config replace on the sync path)."""
        st = self.streams[stream]
        st.theta_cls = theta_cls
        st.theta_loc = theta_loc
        self.monitor.log_event("stream_thresholds",
                               t=t if t is not None else 0.0,
                               stream=stream, theta_cls=theta_cls,
                               theta_loc=theta_loc)

    def hot_swap_ensemble(self, snaps, omega, *, version=None,
                          t: Optional[float] = None,
                          stream: Optional[str] = None) -> int:
        """Swap an Eq. 9 snapshot ensemble into live serving.

        The stream's classify stage switches to the multi-readout
        ``fog.classify_ensemble`` / ``fog.classify_ensemble_batched``
        variant scoring against the whole lineage; ``W`` (the latest
        promoted readout) is untouched — the learning plane keeps using it
        to rescore label candidates.  Same zero-loss semantics as
        :meth:`hot_swap`."""
        snaps = np.asarray(snaps)
        omega = np.asarray(omega)
        targets = self._swap_targets(stream)
        inflight = sum(1 for s in targets if s.busy)
        for s in targets:
            s.set_ensemble(snaps, omega)
        self.monitor.incr("hot_swaps")
        self.monitor.log_event("hot_swap", t=t if t is not None else 0.0,
                               version=version, inflight=inflight,
                               stream=stream, kind="ensemble",
                               snapshots=int(snaps.shape[0]))
        return inflight

    # ------------------------------------------------------------------
    def throughput_report(self) -> Dict[str, float]:
        """Wall-clock + simulated throughput of the detect stage, batch
        stats, replica pool size, and SLO attainment (when SLOs are set)."""
        d = dict(self.detect_stats)
        d["frames_per_s"] = (d["frames"] / d["wall_s"] if d["wall_s"] > 0
                             else 0.0)
        d.update({f"batch_{k}": v for k, v in self.batcher.stats.items()})
        d["replicas"] = len(self.router.replicas)
        d["healthy_replicas"] = self.router.healthy_count()
        d["hot_path"] = self.hot_path
        hps = self.hot_path_stats
        d.update({f"hot_{k}": v for k, v in hps.items()})
        if hps["flushes"]:
            d["host_syncs_per_flush"] = hps["host_syncs"] / hps["flushes"]
        if hps["crops_budget"]:
            # fraction of full-budget fog-classify FLOPs the compacted
            # (bucketed) gather avoided this run
            d["classify_flops_saved_frac"] = (
                1.0 - hps["crops_classified"] / hps["crops_budget"])
        d["w_uploads"] = sum(s.w_uploads for s in self.streams.values())
        d["e_uploads"] = sum(s.e_uploads for s in self.streams.values())
        ss = self.sched_stats
        d.update({f"sched_{k}": v for k, v in ss.items()})
        if ss["finalizes"]:
            # event-loop wall net of payload assembly + model dispatch,
            # amortized per finalized chunk: the fleet-scale flatness metric
            d["sched_overhead_per_chunk_s"] = (
                max(0.0, ss["step_wall_s"] - ss["model_wall_s"])
                / ss["finalizes"])
        if self.store is not None:
            d["store"] = self.store.report()
            # capacity-pressure evictions, surfaced at top level so the
            # regression gate (and the CostModel's spill charge) see them
            d["store_spills"] = self.store.stats["spills"]
        if self.tenant_stats["flushes"]:
            d.update({f"tenant_{k}": v for k, v in self.tenant_stats.items()})
        if self.cost_model is not None:
            store_stats = (self.store.report() if self.store is not None
                           else None)
            d["cost"] = self.cost_model.cost_report(store_stats)
            d["tenants"] = self._tenant_report()
        # per-field lazy-result ledger: which result fields were actually
        # downloaded (a HITL-off run must never pay for fog_features)
        d["field_downloads"] = dict(self.field_downloads)
        # chaos plane: emitted unconditionally (zeros on fault-free runs)
        # so plain and idle-injector reports stay key-for-key identical
        d.update({f"chaos_{k}": v for k, v in self.chaos_stats.items()})
        d["chaos_route_timeouts"] = self.router.timeouts
        # warm-pool plane: same unconditional-zeros discipline as chaos_*
        d.update({f"warm_{k}": v for k, v in self.warm_stats.items()})
        # simulated detect-stage makespan across the replica pool: with R
        # replicas the sub-batches overlap, so frames/span is the serving
        # plane's *capacity*, unlike frames/wall_s (one-CPU jit time)
        if self._detect_windows:
            t_lo = min(s for s, _ in self._detect_windows)
            t_hi = max(s + dur for s, dur in self._detect_windows)
            span = t_hi - t_lo
            d["detect_span_s"] = span
            d["sim_frames_per_s"] = (d["frames"] / span if span > 0 else 0.0)
            # detect-device occupancy: busy fraction of the replica pool
            # over the detect span (a starved accelerator reads low here);
            # computed from _detect_windows because retired replicas take
            # their ExecutionRecords with them.  The shared fog-batch
            # executor never retires, so it reports via busy_fraction.
            busy = sum(dur for _, dur in self._detect_windows)
            pool = max(1, len(self.router.replicas))
            d["detect_occupancy"] = (min(1.0, busy / (span * pool))
                                     if span > 0 else 0.0)
            d["fog_batch_occupancy"] = self.fog_batch_exec.busy_fraction(
                t_lo, t_hi)
        att = self.monitor.values("slo_attained")
        if att:
            d["slo_attainment"] = float(np.mean(att))
        if self.autoscaler is not None and self.autoscaler.history:
            s = self.autoscaler.summary()
            d["peak_devices"] = s["peak_devices"]
            d["peak_queue"] = s["peak_queue"]
        return d

    def _tenant_report(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant latency percentiles + SLO attainment, enumerated from
        the monitor's tagged series (sharded-safe: shards share the
        monitor, so every shard reports the same complete view)."""
        out: Dict[str, Dict[str, float]] = {}
        for tag in self.monitor.tags("latency"):
            att = self.monitor.values(f"slo_attained:{tag}")
            out[tag] = {
                "chunks": len(self.monitor.values(f"latency:{tag}")),
                "p50_latency_s": self.monitor.percentile(f"latency:{tag}",
                                                         50),
                "p99_latency_s": self.monitor.percentile(f"latency:{tag}",
                                                         99),
                "slo_attainment": float(np.mean(att)) if att else 1.0,
            }
        return out
