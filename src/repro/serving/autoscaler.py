"""Provisioner / autoscaler (Fig. 16): scale the cloud GPU pool with load.

The decision is unit-agnostic: ``decide`` maps (queue backlog, current
capacity) -> new capacity.  The ``Router`` applies it either to a replica's
simulated *device* pool (``scale_unit="devices"``) or to the number of
whole executor *replicas* in its pool (``scale_unit="replicas"`` — the
cloud ML server's autoscaled replica pool that batches are sharded
across).  ``unit`` only labels the trace for monitoring."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Autoscaler:
    min_devices: int = 1
    max_devices: int = 8
    target_queue_per_device: float = 2.0
    scale_down_queue: float = 0.5
    cooldown_s: float = 2.0
    unit: str = "devices"         # "devices" | "replicas" (trace label)

    _last_change: float = -1e9
    history: List[Dict[str, float]] = field(default_factory=list)

    def decide(self, now: float, queue_len: int, devices: int) -> int:
        """Returns the new device count."""
        new = devices
        per_dev = queue_len / max(devices, 1)
        if per_dev > self.target_queue_per_device:
            new = min(self.max_devices, devices + 1 + int(
                per_dev // (2 * self.target_queue_per_device)))
        elif per_dev < self.scale_down_queue and devices > self.min_devices:
            new = devices - 1
        if new != devices and now - self._last_change < self.cooldown_s:
            new = devices
        if new != devices:
            self._last_change = now
        self.history.append({"t": now, "queue": queue_len,
                             "devices": devices, "new_devices": new})
        return new

    def summary(self) -> Dict[str, float]:
        """Aggregate view of the scaling trace (for benchmarks/monitoring)."""
        if not self.history:
            return {"decisions": 0, "peak_queue": 0, "peak_devices": 0,
                    "scale_ups": 0, "scale_downs": 0, "unit": self.unit}
        return {
            "unit": self.unit,
            "decisions": len(self.history),
            "peak_queue": max(h["queue"] for h in self.history),
            "peak_devices": max(h["new_devices"] for h in self.history),
            "scale_ups": sum(h["new_devices"] > h["devices"]
                             for h in self.history),
            "scale_downs": sum(h["new_devices"] < h["devices"]
                               for h in self.history),
        }


@dataclass
class CostAwareAutoscaler(Autoscaler):
    """Scale the replica pool to minimise $ subject to SLO attainment.

    Replaces the queue-depth heuristic with an explicit economic objective:

    * **Upward** pressure is SLO-driven.  The pool needed to drain the
      (EWMA-smoothed) backlog within the per-chunk SLO slack is
      ``ceil(demand * frame_service_s / (slo_slack_s - cold_start_s))`` —
      the cold-start term discounts the slack because a replica spun up
      *now* contributes nothing for ``cold_start_s`` simulated seconds
      (``Router(cold_start_s=)``).  When that exceeds the current pool we
      scale up immediately: an SLO miss is priced at ``miss_value_usd``
      per chunk, which dominates keep-alive for any sane price book.
    * **Downward** pressure is keep-alive cost.  Retiring one replica
      saves ``replica_rate_usd_s`` $/s, but if demand returns we pay the
      cold-start latency (valued at ``miss_value_usd``).  The break-even
      idle horizon is ``miss_value_usd / replica_rate_usd_s`` seconds —
      we shed a replica only after demand has stayed below the smaller
      pool's capacity for that long, one replica at a time.

    History rows keep the base-class keys so ``summary()`` and the
    schedulers' ``peak_devices``/``peak_queue`` reporting work unchanged.
    """
    replica_rate_usd_s: float = 0.004   # keep-alive $ per replica-second
    frame_service_s: float = 1.0 / 75.0  # service time per queued frame
    slo_slack_s: float = 1.0            # per-chunk latency budget to drain
    cold_start_s: float = 0.0           # mirror of Router(cold_start_s=)
    miss_value_usd: float = 0.004       # $ value assigned to one SLO miss
    ewma_alpha: float = 0.4

    _ewma_queue: float = 0.0
    _low_since: Optional[float] = None

    def decide(self, now: float, queue_len: int, devices: int) -> int:
        self._ewma_queue += self.ewma_alpha * (queue_len - self._ewma_queue)
        demand = max(float(queue_len), self._ewma_queue)
        headroom = max(self.slo_slack_s - self.cold_start_s, 1e-6)
        needed = math.ceil(demand * self.frame_service_s / headroom)
        needed = min(self.max_devices, max(self.min_devices, needed))
        new = devices
        if needed > devices:
            new = needed
            self._low_since = None
        elif needed < devices:
            grace = self.miss_value_usd / max(self.replica_rate_usd_s, 1e-9)
            if self._low_since is None:
                self._low_since = now
            if now - self._low_since >= grace and devices > self.min_devices:
                new = devices - 1
                self._low_since = now
        else:
            self._low_since = None
        self.history.append({"t": now, "queue": queue_len,
                             "devices": devices, "new_devices": new,
                             "needed": needed,
                             "ewma_queue": self._ewma_queue})
        return new
