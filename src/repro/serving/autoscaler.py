"""Provisioner / autoscaler (Fig. 16): scale the cloud GPU pool with load.

The decision is unit-agnostic: ``decide`` maps (queue backlog, current
capacity) -> new capacity.  The ``Router`` applies it either to a replica's
simulated *device* pool (``scale_unit="devices"``) or to the number of
whole executor *replicas* in its pool (``scale_unit="replicas"`` — the
cloud ML server's autoscaled replica pool that batches are sharded
across).  ``unit`` only labels the trace for monitoring."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class Autoscaler:
    min_devices: int = 1
    max_devices: int = 8
    target_queue_per_device: float = 2.0
    scale_down_queue: float = 0.5
    cooldown_s: float = 2.0
    unit: str = "devices"         # "devices" | "replicas" (trace label)

    _last_change: float = -1e9
    history: List[Dict[str, float]] = field(default_factory=list)

    def decide(self, now: float, queue_len: int, devices: int) -> int:
        """Returns the new device count."""
        new = devices
        per_dev = queue_len / max(devices, 1)
        if per_dev > self.target_queue_per_device:
            new = min(self.max_devices, devices + 1 + int(
                per_dev // (2 * self.target_queue_per_device)))
        elif per_dev < self.scale_down_queue and devices > self.min_devices:
            new = devices - 1
        if new != devices and now - self._last_change < self.cooldown_s:
            new = devices
        if new != devices:
            self._last_change = now
        self.history.append({"t": now, "queue": queue_len,
                             "devices": devices, "new_devices": new})
        return new

    def summary(self) -> Dict[str, float]:
        """Aggregate view of the scaling trace (for benchmarks/monitoring)."""
        if not self.history:
            return {"decisions": 0, "peak_queue": 0, "peak_devices": 0,
                    "scale_ups": 0, "scale_downs": 0, "unit": self.unit}
        return {
            "unit": self.unit,
            "decisions": len(self.history),
            "peak_queue": max(h["queue"] for h in self.history),
            "peak_devices": max(h["new_devices"] for h in self.history),
            "scale_ups": sum(h["new_devices"] > h["devices"]
                             for h in self.history),
            "scale_downs": sum(h["new_devices"] < h["devices"]
                               for h in self.history),
        }
