"""Provisioner / autoscaler (Fig. 16): scale the cloud GPU pool with load.

The decision is unit-agnostic: ``decide`` maps (queue backlog, current
capacity) -> new capacity.  The ``Router`` applies it either to a replica's
simulated *device* pool (``scale_unit="devices"``) or to the number of
whole executor *replicas* in its pool (``scale_unit="replicas"`` — the
cloud ML server's autoscaled replica pool that batches are sharded
across).  ``unit`` only labels the trace for monitoring."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Autoscaler:
    min_devices: int = 1
    max_devices: int = 8
    target_queue_per_device: float = 2.0
    scale_down_queue: float = 0.5
    cooldown_s: float = 2.0
    unit: str = "devices"         # "devices" | "replicas" (trace label)

    _last_change: float = -1e9
    history: List[Dict[str, float]] = field(default_factory=list)

    def decide(self, now: float, queue_len: int, devices: int) -> int:
        """Returns the new device count."""
        new = devices
        per_dev = queue_len / max(devices, 1)
        if per_dev > self.target_queue_per_device:
            new = min(self.max_devices, devices + 1 + int(
                per_dev // (2 * self.target_queue_per_device)))
        elif per_dev < self.scale_down_queue and devices > self.min_devices:
            new = devices - 1
        if new != devices and now - self._last_change < self.cooldown_s:
            new = devices
        if new != devices:
            self._last_change = now
        self.history.append({"t": now, "queue": queue_len,
                             "devices": devices, "new_devices": new})
        return new

    def summary(self) -> Dict[str, float]:
        """Aggregate view of the scaling trace (for benchmarks/monitoring)."""
        if not self.history:
            return {"decisions": 0, "peak_queue": 0, "peak_devices": 0,
                    "scale_ups": 0, "scale_downs": 0, "unit": self.unit}
        return {
            "unit": self.unit,
            "decisions": len(self.history),
            "peak_queue": max(h["queue"] for h in self.history),
            "peak_devices": max(h["new_devices"] for h in self.history),
            "scale_ups": sum(h["new_devices"] > h["devices"]
                             for h in self.history),
            "scale_downs": sum(h["new_devices"] < h["devices"]
                               for h in self.history),
        }


class DiurnalForecaster:
    """Per-tenant arrival-rate forecaster on the simulated clock.

    Arrivals are accumulated into fixed ``bin_s`` buckets.  Two estimators
    run over the bin series:

    * an **EWMA rate** — the reactive fallback, always available;
    * a **diurnal profile** — once ≥2 periods of history exist, a
      normalized autocorrelation scan over candidate lags detects the
      dominant period (smallest lag within 95% of the best correlation,
      so harmonics at 2L/3L never shadow the fundamental).  The per-phase
      mean of the bins then forecasts the rate at any *future* simulated
      time, which is what lets the warm pool spin replicas up *before* a
      burst instead of reacting to its backlog.

    Everything is pure python over a few hundred bins — deterministic and
    cheap enough to re-run per arrival (results are memoized on the
    observation count)."""

    def __init__(self, bin_s: float = 0.25, ewma_alpha: float = 0.3,
                 min_corr: float = 0.5, burst_frac: float = 0.5,
                 max_period_bins: int = 512):
        self.bin_s = bin_s
        self.ewma_alpha = ewma_alpha
        self.min_corr = min_corr
        self.burst_frac = burst_frac
        self.max_period_bins = max_period_bins
        self._bins: List[float] = []
        self.observations = 0
        self._cache_key: Tuple[int, int] = (-1, -1)
        self._cache: Tuple[Optional[int], Optional[List[float]]] = (None,
                                                                    None)

    def observe(self, t: float, frames: float) -> None:
        idx = max(0, int(t / self.bin_s))
        while len(self._bins) <= idx:
            self._bins.append(0.0)
        self._bins[idx] += float(frames)
        self.observations += 1

    # -- estimators ------------------------------------------------------
    def ewma_rate(self) -> float:
        """EWMA arrival rate (frames/s) over the whole bin history — empty
        bins decay it, so a quiet stretch reads as a low rate."""
        e = 0.0
        for v in self._bins:
            e += self.ewma_alpha * (v - e)
        return e / self.bin_s

    def _analyze(self) -> Tuple[Optional[int], Optional[List[float]]]:
        """(period_bins, per-phase mean profile), memoized; (None, None)
        until a period is detectable."""
        key = (len(self._bins), self.observations)
        if key == self._cache_key:
            return self._cache
        x, n = self._bins, len(self._bins)
        best_lag: Optional[int] = None
        if n >= 8:
            mu = sum(x) / n
            var = sum((v - mu) ** 2 for v in x) / n
            if var > 1e-12:
                max_lag = min(n // 2, self.max_period_bins)
                corr: Dict[int, float] = {}
                best_r = 0.0
                for lag in range(2, max_lag + 1):
                    m = n - lag
                    # biased ACF estimator (divide by n, not m): overlap
                    # shrinkage damps long lags, so a harmonic at 2L can
                    # never outscore the fundamental on sparse history
                    c = sum((x[i] - mu) * (x[i + lag] - mu)
                            for i in range(m)) / (n * var)
                    corr[lag] = c
                    if c > best_r:
                        best_r, best_lag = c, lag
                if best_lag is None or best_r < self.min_corr:
                    best_lag = None
                else:
                    for lag in sorted(corr):
                        if corr[lag] >= 0.95 * best_r:
                            best_lag = lag
                            break
        profile: Optional[List[float]] = None
        if best_lag:
            length = best_lag
            periods = n // length
            profile = [
                sum(x[p * length + i] for p in range(periods)) / periods
                for i in range(length)]
        self._cache_key = key
        self._cache = (best_lag, profile)
        return self._cache

    @property
    def period_s(self) -> Optional[float]:
        lag, _ = self._analyze()
        return lag * self.bin_s if lag else None

    def rate_at(self, t: float) -> float:
        """Forecast arrival rate (frames/s) at simulated ``t`` — the
        diurnal profile when detected, the EWMA fallback otherwise."""
        lag, profile = self._analyze()
        if lag:
            return profile[int(t / self.bin_s) % lag] / self.bin_s
        return self.ewma_rate()

    def volume_in_window(self, t0: float, t1: float) -> float:
        """Forecast frames arriving in ``[t0, t1)``."""
        if t1 <= t0:
            return 0.0
        lag, profile = self._analyze()
        if not lag:
            return self.ewma_rate() * (t1 - t0)
        b0, b1 = int(t0 / self.bin_s), int(math.ceil(t1 / self.bin_s))
        return sum(profile[k % lag] for k in range(b0, b1))

    def _thr(self, profile: List[float]) -> float:
        return self.burst_frac * max(profile)

    def next_burst_after(self, t: float) -> Optional[float]:
        """Predicted start of the next burst strictly after ``t`` (rising
        edge of the profile through ``burst_frac * peak``), or ``None``
        while no period is detected."""
        lag, profile = self._analyze()
        if not lag or max(profile) <= 0:
            return None
        thr = self._thr(profile)
        k0 = int(t / self.bin_s)
        for k in range(k0 + 1, k0 + 2 * lag + 1):
            if profile[k % lag] >= thr and profile[(k - 1) % lag] < thr:
                return k * self.bin_s
        return None

    def burst_end_after(self, t: float) -> Optional[float]:
        """Predicted end of the burst active at/after ``t`` (falling
        edge), or ``None`` while no period is detected."""
        lag, profile = self._analyze()
        if not lag or max(profile) <= 0:
            return None
        thr = self._thr(profile)
        k0 = int(t / self.bin_s)
        for k in range(k0 + 1, k0 + 2 * lag + 1):
            if profile[k % lag] < thr and profile[(k - 1) % lag] >= thr:
                return k * self.bin_s
        return None


@dataclass
class WarmPoolPolicy:
    """Predictive warm-pool management: prewarm ahead of forecast bursts,
    keep-alive sized by the break-even $ tradeoff.

    Two decisions, both driven by per-tenant :class:`DiurnalForecaster`
    state fed from the scheduler's arrival events:

    * **Prewarm-ahead**: when the forecast sees the next burst, the
      scheduler fires a warm check ``cold_start_s + prewarm_margin_s``
      *before* its predicted start, so spin-up completes off the critical
      path and the burst lands on warm replicas.
    * **Keep-alive vs cold start**: holding a replica warm costs
      ``replica_rate_usd_s`` $/s; letting it go cold risks one SLO miss
      worth ``miss_value_usd`` when demand returns.  The break-even
      horizon is ``miss_value_usd / replica_rate_usd_s`` seconds: a pool
      is kept warm through gaps shorter than that, and shed to
      ``min_replicas`` across longer gaps (the prewarm-ahead check
      restores it in time, so the cold start still stays off the
      critical path).

    ``enabled=False`` (or simply not attaching a policy) disables every
    decision — the serving plane then stays bitwise-identical to the
    policy-free scheduler; ``bench_coldstart`` gates this at 1 and K
    shards.  One policy instance is shared across scheduler shards, like
    the router it steers."""
    cold_start_s: float = 0.0
    replica_rate_usd_s: float = 0.004   # keep-alive $/replica-s (CostModel)
    miss_value_usd: float = 0.004       # $ value of one cold-start SLO miss
    frame_service_s: float = 1.0 / 75.0
    slo_slack_s: float = 0.5            # drain budget for a forecast burst
    min_replicas: int = 1
    max_replicas: int = 8
    prewarm_margin_s: float = 0.05      # spin-up must land before the burst
    drain_margin_s: float = 0.5         # shed check delay after a burst end
    bin_s: float = 0.25
    enabled: bool = True
    # forecast checks allowed per observation epoch: one shed (after the
    # current burst drains) + one prewarm (ahead of the next burst); the
    # cap is what makes the check chain terminate when traffic stops
    max_checks_per_obs: int = 2

    forecasters: Dict[str, DiurnalForecaster] = field(default_factory=dict)
    stats: Dict[str, float] = field(default_factory=lambda: {
        "observations": 0, "checks": 0})
    _pending: Optional[float] = None
    _fires_since_obs: int = 0

    # -- economics -------------------------------------------------------
    @property
    def keep_warm_horizon_s(self) -> float:
        """Break-even idle gap: keep-alive for longer than this costs more
        than the cold start it avoids."""
        return self.miss_value_usd / max(self.replica_rate_usd_s, 1e-9)

    def _clamp(self, n: int) -> int:
        return min(self.max_replicas, max(self.min_replicas, n))

    # -- forecast feed ---------------------------------------------------
    def observe(self, t: float, frames: float,
                tenant: str = "default") -> None:
        fc = self.forecasters.get(tenant)
        if fc is None:
            fc = self.forecasters[tenant] = DiurnalForecaster(
                bin_s=self.bin_s)
        fc.observe(t, frames)
        self.stats["observations"] += 1
        self._fires_since_obs = 0

    def rate_at(self, t: float) -> float:
        return sum(fc.rate_at(t) for fc in self.forecasters.values())

    def volume_in_window(self, t0: float, t1: float) -> float:
        return sum(fc.volume_in_window(t0, t1)
                   for fc in self.forecasters.values())

    def next_burst_after(self, t: float) -> Optional[float]:
        ts = [fc.next_burst_after(t) for fc in self.forecasters.values()]
        ts = [x for x in ts if x is not None]
        return min(ts) if ts else None

    def burst_end_after(self, t: float) -> Optional[float]:
        ts = [fc.burst_end_after(t) for fc in self.forecasters.values()]
        ts = [x for x in ts if x is not None]
        return min(ts) if ts else None

    # -- pool sizing -----------------------------------------------------
    def target_replicas(self, now: float) -> int:
        """Warm replicas the pool should hold at ``now``.

        Imminent forecast demand (arrivals inside the spin-up lookahead
        plus the drain budget) sizes the pool to drain that volume within
        ``slo_slack_s``.  With nothing imminent, the break-even rule
        applies: hold the next burst's pool through a gap shorter than
        ``keep_warm_horizon_s``, shed to ``min_replicas`` otherwise."""
        if not self.enabled:
            return self.min_replicas
        look = self.cold_start_s + self.prewarm_margin_s + max(
            self.slo_slack_s, self.bin_s)
        vol = self.volume_in_window(now, now + look)
        if vol > 0:
            return self._clamp(int(math.ceil(
                vol * self.frame_service_s / max(self.slo_slack_s, 1e-6))))
        nb = self.next_burst_after(now)
        if nb is not None and nb - now <= self.keep_warm_horizon_s:
            vol = self.volume_in_window(nb, nb + max(self.slo_slack_s,
                                                     self.bin_s))
            return self._clamp(int(math.ceil(
                vol * self.frame_service_s / max(self.slo_slack_s, 1e-6))))
        return self.min_replicas

    # -- check scheduling (the scheduler turns these into events) --------
    def next_check(self, now: float) -> Optional[float]:
        """Simulated time of the next warm-pool check, or ``None``.

        At most one check is outstanding at a time, and at most
        ``max_checks_per_obs`` fire per observation epoch (shed after the
        current burst drains, prewarm ahead of the next one) — new
        arrivals reset the budget, so the chain is self-sustaining under
        live traffic and self-terminating when traffic stops."""
        if not self.enabled or self._pending is not None \
                or self._fires_since_obs >= self.max_checks_per_obs:
            return None
        cands = []
        be = self.burst_end_after(now)
        if be is not None:
            cands.append(be + self.drain_margin_s)
        nb = self.next_burst_after(now)
        if nb is not None:
            cands.append(nb - self.cold_start_s - self.prewarm_margin_s)
        if self._fires_since_obs > 0:
            # a check just fired at `now`: only strictly-future candidates
            # may chain, so a late prewarm can't re-fire in place and burn
            # the epoch's remaining slot
            cands = [c for c in cands if c > now + 1e-9]
        if not cands:
            return None
        t = max(now, min(cands))
        self._pending = t
        self.stats["checks"] += 1
        return t

    def fired(self) -> None:
        """A scheduled check fired (scheduler callback)."""
        self._pending = None
        self._fires_since_obs += 1


@dataclass
class CostAwareAutoscaler(Autoscaler):
    """Scale the replica pool to minimise $ subject to SLO attainment.

    Replaces the queue-depth heuristic with an explicit economic objective:

    * **Upward** pressure is SLO-driven.  The pool needed to drain the
      (EWMA-smoothed) backlog within the per-chunk SLO slack is
      ``ceil(demand * frame_service_s / (slo_slack_s - cold_start_s))`` —
      the cold-start term discounts the slack because a replica spun up
      *now* contributes nothing for ``cold_start_s`` simulated seconds
      (``Router(cold_start_s=)``).  When that exceeds the current pool we
      scale up immediately: an SLO miss is priced at ``miss_value_usd``
      per chunk, which dominates keep-alive for any sane price book.
    * **Downward** pressure is keep-alive cost.  Retiring one replica
      saves ``replica_rate_usd_s`` $/s, but if demand returns we pay the
      cold-start latency (valued at ``miss_value_usd``).  The break-even
      idle horizon is ``miss_value_usd / replica_rate_usd_s`` seconds —
      we shed a replica only after demand has stayed below the smaller
      pool's capacity for that long, one replica at a time.

    With a :class:`WarmPoolPolicy` attached (``warm_pool=``), the upward
    demand signal comes from the policy's *forecast* instead of only the
    observed backlog: ``needed`` is floored at the forecast pool target,
    so the pool is already sized for a predicted burst before its queue
    materializes, and the break-even scale-down never undercuts the warm
    floor the policy wants held ahead of the next burst.  A ``None`` (or
    disabled) policy leaves every decision bitwise-identical to the
    backlog-reactive behaviour.

    History rows keep the base-class keys so ``summary()`` and the
    schedulers' ``peak_devices``/``peak_queue`` reporting work unchanged.
    """
    replica_rate_usd_s: float = 0.004   # keep-alive $ per replica-second
    frame_service_s: float = 1.0 / 75.0  # service time per queued frame
    slo_slack_s: float = 1.0            # per-chunk latency budget to drain
    cold_start_s: float = 0.0           # mirror of Router(cold_start_s=)
    miss_value_usd: float = 0.004       # $ value assigned to one SLO miss
    ewma_alpha: float = 0.4
    warm_pool: Optional[WarmPoolPolicy] = None

    _ewma_queue: float = 0.0
    _low_since: Optional[float] = None

    def decide(self, now: float, queue_len: int, devices: int) -> int:
        self._ewma_queue += self.ewma_alpha * (queue_len - self._ewma_queue)
        demand = max(float(queue_len), self._ewma_queue)
        headroom = max(self.slo_slack_s - self.cold_start_s, 1e-6)
        needed = math.ceil(demand * self.frame_service_s / headroom)
        if self.warm_pool is not None and self.warm_pool.enabled:
            needed = max(needed, self.warm_pool.target_replicas(now))
        needed = min(self.max_devices, max(self.min_devices, needed))
        new = devices
        if needed > devices:
            new = needed
            self._low_since = None
        elif needed < devices:
            grace = self.miss_value_usd / max(self.replica_rate_usd_s, 1e-9)
            if self._low_since is None:
                self._low_since = now
            if now - self._low_since >= grace and devices > self.min_devices:
                new = devices - 1
                self._low_since = now
        else:
            self._low_since = None
        self.history.append({"t": now, "queue": queue_len,
                             "devices": devices, "new_devices": new,
                             "needed": needed,
                             "ewma_queue": self._ewma_queue})
        return new
