"""Request router / load balancer (the cloud ML server's load balancer in
Fig. 3): routes requests across executor replicas with health checks and
least-loaded selection; integrates with the autoscaler.

Scaling has two units: ``scale_unit="devices"`` grows the picked replica's
simulated device pool in place (the pre-SLO behaviour), while
``scale_unit="replicas"`` adds/removes whole executor replicas through
``replica_factory`` — the cloud ML server's autoscaled replica pool, which
the graph scheduler shards batches across.

Two pick policies: ``"least"`` scans every healthy replica for the lowest
(inflight, earliest-free-device) load — exact, but O(R) of *coordinated*
state per dispatch, which is the contended read when many scheduler shards
share one pool.  ``"p2c"`` is power-of-two-choices: sample two distinct
healthy replicas and take the less loaded, which keeps max load within
O(log log R) of optimal while touching only two replicas' state.  The
sample stream is seeded and deterministic, so sharded runs stay
reproducible; with a single replica both policies degenerate to it."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.autoscaler import Autoscaler
from repro.serving.executor import Executor
from repro.serving.monitor import Monitor


@dataclass
class Replica:
    executor: Executor
    uid: int = 0          # stable identity: pool positions shift on scaling
    healthy: bool = True
    inflight: int = 0
    served: int = 0
    # serverless spin-up state: a replica is COLD (spinning up) until the
    # simulated clock reaches ready_at, WARM after.  Initial replicas are
    # warm from t=0; scale-up/prewarm sets ready_at = now + cold_start_s.
    # A spinning replica is healthy and routable — its devices are just
    # busy until ready_at — so it participates in hedging and fault
    # handling like any other pool member.
    ready_at: float = 0.0
    # EWMA of observed per-frame service time; the scheduler's hedge
    # decision compares it against the nominal profile rate to spot a
    # straggling replica.  None until the first dispatch completes, and
    # reset on re-admission — stale pre-outage load stats must not starve
    # (or mis-hedge) a recovered replica.
    rate_ewma: Optional[float] = None


class Router:
    """Least-loaded routing with health checks over executor replicas."""

    def __init__(self, replicas: List[Executor],
                 monitor: Optional[Monitor] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 scale_unit: str = "devices",
                 replica_factory: Optional[Callable[[int], Executor]] = None,
                 cold_start_s: float = 0.0,
                 pick_policy: str = "least", pick_seed: int = 0):
        assert scale_unit in ("devices", "replicas")
        assert pick_policy in ("least", "p2c")
        self.pick_policy = pick_policy
        self._pick_rng = np.random.default_rng(pick_seed)
        self.replicas = [Replica(e, uid=i) for i, e in enumerate(replicas)]
        self._next_uid = len(self.replicas)
        self.monitor = monitor or Monitor()
        self.autoscaler = autoscaler
        self.scale_unit = scale_unit
        self.replica_factory = replica_factory
        # serverless container spin-up: a replica added at simulated time t
        # serves its first request no earlier than t + cold_start_s (its
        # devices start busy, not free-at-t=0)
        self.cold_start_s = cold_start_s
        # optional tenancy CostModel: when set, every pool-size change is
        # observed as a (t, healthy) point so provisioned replica-seconds
        # (keep-alive spend) can be integrated at report time
        self.cost_model = None
        self._queue: List[Tuple[str, tuple, dict, float]] = []
        self.clock = 0.0
        self.timeouts = 0     # dispatches that exceeded their SLO timeout

    # ------------------------------------------------------------------
    def mark_unhealthy(self, idx: int, now: Optional[float] = None) -> None:
        """Fail a replica.  Passing ``now`` closes the keep-alive billing
        interval at the failure time — a dead replica stops accruing
        provisioned replica-seconds immediately, not at the next
        ``scale_replicas`` sweep."""
        self.replicas[idx].healthy = False
        self.monitor.incr("health_check_failures")
        if now is not None and self.cost_model is not None:
            self.cost_model.observe_pool(now, self.healthy_count())

    def mark_healthy(self, idx: int) -> None:
        self.replicas[idx].healthy = True

    def readmit(self, idx: int, now: float) -> bool:
        """Bring a flapped replica back into rotation at simulated ``now``.

        Load state accumulated before the outage is stale — inflight
        counts, the service-rate EWMA, and device busy horizons all
        describe a replica that no longer exists — so everything resets;
        its devices come up free at ``now``.  Returns False if the
        replica was already healthy (duplicate probe chains no-op)."""
        rep = self.replicas[idx]
        if rep.healthy:
            return False
        rep.healthy = True
        rep.inflight = 0
        rep.rate_ewma = None
        ex = rep.executor
        # a replica flapped *mid-spin-up* was never warm: re-admission
        # resumes the remaining spin-up (devices free at ready_at), it
        # does not skip it.  Warm replicas (ready_at <= now) come up free
        # at `now` exactly as before.
        ex.busy_until = [max(now, rep.ready_at)] * len(ex.busy_until)
        ex.clock = max(ex.clock, now)
        self.monitor.incr("replica_readmits")
        if self.cost_model is not None:
            self.cost_model.observe_pool(now, self.healthy_count())
        return True

    def healthy_count(self) -> int:
        return sum(r.healthy for r in self.replicas)

    def warm_count(self, now: float) -> int:
        """Healthy replicas whose spin-up has completed at ``now``."""
        return sum(r.healthy and r.ready_at <= now + 1e-12
                   for r in self.replicas)

    def spinning_count(self, now: float) -> int:
        """Healthy replicas still inside their spin-up window at ``now``
        (spin-up-in-progress — provisioned, billed, but not warm yet)."""
        return sum(r.healthy and r.ready_at > now + 1e-12
                   for r in self.replicas)

    def pick(self) -> Optional[int]:
        healthy = [i for i, r in enumerate(self.replicas) if r.healthy]
        if not healthy:
            return None
        if self.pick_policy == "p2c" and len(healthy) > 2:
            # power-of-two-choices on queue depth: two deterministic
            # samples, pick the less loaded of the pair
            a, b = self._pick_rng.choice(len(healthy), size=2,
                                         replace=False)
            healthy = [healthy[int(a)], healthy[int(b)]]
        # least-loaded: fewest inflight, then earliest-free device
        load = [(self.replicas[i].inflight,
                 min(self.replicas[i].executor.busy_until), i)
                for i in healthy]
        return min(load)[2]

    # ------------------------------------------------------------------
    def scale_replicas(self, target: int,
                       now: Optional[float] = None,
                       prewarm: bool = False) -> None:
        """Grow/shrink the pool to ``target`` *healthy* replicas
        (``scale_unit="replicas"``): dead replicas hold no capacity, so
        they are swept out first and never counted toward the target.

        A replica added at simulated ``now`` models serverless container
        spin-up: its devices come up busy until ``now + cold_start_s``
        instead of free-at-t=0.  ``prewarm=True`` tags the additions as
        warm-pool prewarms (the :class:`WarmPoolPolicy` spinning replicas
        up *ahead* of forecast demand, so they are warm when it lands) —
        the mechanics are identical, only the monitoring differs."""
        target = max(1, target)
        now = self.clock if now is None else now
        for i in range(len(self.replicas) - 1, 0, -1):
            if (not self.replicas[i].healthy
                    and self.replicas[i].inflight == 0):
                self.replicas.pop(i)
                self.monitor.incr("replicas_removed")
        while (self.healthy_count() < target
               and self.replica_factory is not None):
            uid = self._next_uid
            self._next_uid += 1
            ex = self.replica_factory(uid)
            ready_at = now + self.cold_start_s
            ex.clock = max(ex.clock, now)
            ex.busy_until = [ready_at] * len(ex.busy_until)
            self.replicas.append(Replica(ex, uid=uid, ready_at=ready_at))
            self.monitor.incr("replicas_added")
            if prewarm:
                self.monitor.incr("replicas_prewarmed")
                self.monitor.record("replica_prewarm", self.cold_start_s,
                                    now)
            if self.cold_start_s > 0:
                self.monitor.record("replica_cold_start", self.cold_start_s,
                                    now)
        while self.healthy_count() > target:
            # retire idle healthy replicas from the tail; replica 0 is the
            # primary and always survives (schedulers hold a reference)
            idx = next((i for i in range(len(self.replicas) - 1, 0, -1)
                        if self.replicas[i].inflight == 0
                        and self.replicas[i].healthy), None)
            if idx is None:
                break
            self.replicas.pop(idx)
            self.monitor.incr("replicas_removed")
        if self.cost_model is not None:
            self.cost_model.observe_pool(now, self.healthy_count())

    # ------------------------------------------------------------------
    def route(self, fn_name: str, *args, now: Optional[float] = None,
              model_time: Optional[float] = None,
              queue_depth: Optional[int] = None,
              replica: Optional[int] = None,
              timeout: Optional[float] = None, **kw):
        """Dispatch one request; returns (result, completion_time, replica).

        ``queue_depth`` lets callers that maintain a real request queue
        (e.g. the cross-stream graph scheduler) feed the autoscaler the
        actual backlog instead of the per-replica busy-time heuristic.
        ``replica`` pins the request to a specific replica (the scheduler
        uses this after its own pick + fault check).  ``timeout`` is the
        flush's SLO slack: a dispatch whose completion exceeds
        ``now + timeout`` is counted (the scheduler's hedging layer is
        what actually covers the miss)."""
        now = self.clock if now is None else now
        self.clock = max(self.clock, now)
        idx = self.pick() if replica is None else replica
        if idx is None:
            raise RuntimeError("no healthy replicas")
        rep = self.replicas[idx]
        rep.inflight += 1
        try:
            result, done = rep.executor.run(fn_name, *args, now=now,
                                            model_time=model_time, **kw)
        finally:
            rep.inflight -= 1
        rep.served += 1
        if timeout is not None and done - now > timeout + 1e-12:
            self.timeouts += 1
            self.monitor.incr("route_timeouts")
        self.monitor.record("route_latency", done - now, now)
        self.monitor.incr(f"served_replica_{idx}")
        if self.autoscaler is not None:
            if queue_depth is None:
                # queue pressure = backlog seconds ahead of `now`, in units
                # of this request's service time
                backlog = max(0.0, min(rep.executor.busy_until) - now)
                unit = model_time if model_time else max(done - now, 1e-9)
                queue = int(backlog / max(unit, 1e-9))
            else:
                queue = queue_depth
            if self.scale_unit == "replicas":
                # capacity = healthy replicas: a dead one still in the pool
                # must not be counted as provisioned capacity
                current = self.healthy_count()
                target = self.autoscaler.decide(done, queue, current)
                if target != current:
                    self.scale_replicas(target, now=done)
            else:
                target = self.autoscaler.decide(done, queue,
                                                rep.executor.num_devices)
                if target != rep.executor.num_devices:
                    rep.executor.scale_to(target)
        return result, done, idx

    def hedge(self, idx: int, now: float, model_time: float
              ) -> Tuple[float, float]:
        """Book a speculative duplicate of an already-routed dispatch on
        replica ``idx``: occupies real device time and counts as served
        (a hedge is a real invocation) but does not re-run the function —
        the primary's result is bitwise-reused, only the completion time
        race differs.  Returns ``(start, done)``."""
        rep = self.replicas[idx]
        rep.served += 1
        start, done = rep.executor.occupy("hedge", now=now,
                                          model_time=model_time)
        self.monitor.incr(f"served_replica_{idx}")
        return start, done

    def load_report(self) -> Dict[str, float]:
        total = sum(r.served for r in self.replicas) or 1
        shares = [r.served / total for r in self.replicas]
        # Jain's fairness index: 1.0 = perfectly balanced
        fairness = (sum(shares) ** 2 /
                    (len(shares) * sum(s ** 2 for s in shares))
                    if any(shares) else 1.0)
        return {"served": total, "fairness": fairness,
                "replicas": len(self.replicas),
                "healthy": sum(r.healthy for r in self.replicas)}
