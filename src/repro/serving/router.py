"""Request router / load balancer (the cloud ML server's load balancer in
Fig. 3): routes chunks across executor replicas with health checks and
least-loaded selection; integrates with the autoscaler."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serving.autoscaler import Autoscaler
from repro.serving.executor import Executor
from repro.serving.monitor import Monitor


@dataclass
class Replica:
    executor: Executor
    healthy: bool = True
    inflight: int = 0
    served: int = 0


class Router:
    """Least-loaded routing with health checks over executor replicas."""

    def __init__(self, replicas: List[Executor],
                 monitor: Optional[Monitor] = None,
                 autoscaler: Optional[Autoscaler] = None):
        self.replicas = [Replica(e) for e in replicas]
        self.monitor = monitor or Monitor()
        self.autoscaler = autoscaler
        self._queue: List[Tuple[str, tuple, dict, float]] = []
        self.clock = 0.0

    # ------------------------------------------------------------------
    def mark_unhealthy(self, idx: int) -> None:
        self.replicas[idx].healthy = False
        self.monitor.incr("health_check_failures")

    def mark_healthy(self, idx: int) -> None:
        self.replicas[idx].healthy = True

    def _pick(self) -> Optional[int]:
        healthy = [(r.inflight + len(r.executor.busy_until), i)
                   for i, r in enumerate(self.replicas) if r.healthy]
        if not healthy:
            return None
        # least-loaded: fewest inflight, then earliest-free device
        load = [(r.inflight, min(r.executor.busy_until), i)
                for i, r in enumerate(self.replicas) if r.healthy]
        return min(load)[2]

    # ------------------------------------------------------------------
    def route(self, fn_name: str, *args, now: Optional[float] = None,
              model_time: Optional[float] = None,
              queue_depth: Optional[int] = None, **kw):
        """Dispatch one request; returns (result, completion_time, replica).

        ``queue_depth`` lets callers that maintain a real request queue
        (e.g. the cross-stream graph scheduler) feed the autoscaler the
        actual backlog instead of the per-replica busy-time heuristic."""
        now = self.clock if now is None else now
        self.clock = max(self.clock, now)
        idx = self._pick()
        if idx is None:
            raise RuntimeError("no healthy replicas")
        rep = self.replicas[idx]
        rep.inflight += 1
        try:
            result, done = rep.executor.run(fn_name, *args, now=now,
                                            model_time=model_time, **kw)
        finally:
            rep.inflight -= 1
        rep.served += 1
        self.monitor.record("route_latency", done - now, now)
        self.monitor.incr(f"served_replica_{idx}")
        if self.autoscaler is not None:
            if queue_depth is None:
                # queue pressure = backlog seconds ahead of `now`, in units
                # of this request's service time
                backlog = max(0.0, min(rep.executor.busy_until) - now)
                unit = model_time if model_time else max(done - now, 1e-9)
                queue = int(backlog / max(unit, 1e-9))
            else:
                queue = queue_depth
            target = self.autoscaler.decide(done, queue,
                                            rep.executor.num_devices)
            if target != rep.executor.num_devices:
                rep.executor.scale_to(target)
        return result, done, idx

    def load_report(self) -> Dict[str, float]:
        total = sum(r.served for r in self.replicas) or 1
        shares = [r.served / total for r in self.replicas]
        # Jain's fairness index: 1.0 = perfectly balanced
        fairness = (sum(shares) ** 2 /
                    (len(shares) * sum(s ** 2 for s in shares))
                    if any(shares) else 1.0)
        return {"served": total, "fairness": fairness,
                "healthy": sum(r.healthy for r in self.replicas)}
