"""Function executor over a (simulated) accelerator pool.

Runs registered functions; wall-time per call comes either from real CPU
measurement (``measure=True``) or from the device profile model (TPU/GPU
targets).  This is the stateless-server execution layer of Fig. 3.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.bandwidth import DeviceProfile
from repro.serving.registry import FunctionRegistry


@dataclass
class ExecutionRecord:
    fn_name: str
    start: float
    duration: float
    device: str
    ok: bool = True


@dataclass
class Executor:
    """One node's executor (cloud or fog)."""
    name: str
    registry: FunctionRegistry
    profile: DeviceProfile
    num_devices: int = 1
    measure: bool = False          # True: wall-clock; False: profile model

    clock: float = 0.0
    busy_until: List[float] = None
    # background-lane horizon: HITL/maintenance work queues here and never
    # blocks the serving lane (fixes the fog head-of-line hazard where a
    # busy node's own high-priority chunk sat behind collect work)
    bg_busy_until: float = 0.0
    records: List[ExecutionRecord] = field(default_factory=list)

    def __post_init__(self):
        if self.busy_until is None:
            self.busy_until = [0.0] * self.num_devices

    # -- device pool -------------------------------------------------------
    def scale_to(self, n: int) -> None:
        n = max(1, n)
        if n > len(self.busy_until):
            self.busy_until += [self.clock] * (n - len(self.busy_until))
        else:
            self.busy_until = self.busy_until[:n]
        self.num_devices = n

    def _acquire(self, now: float) -> Tuple[int, float]:
        i = min(range(len(self.busy_until)), key=lambda j: self.busy_until[j])
        return i, max(now, self.busy_until[i])

    # -- execution ----------------------------------------------------------
    def run(self, fn_name: str, *args, now: Optional[float] = None,
            model_time: Optional[float] = None, priority: str = "serve",
            **kw) -> Tuple[Any, float]:
        """Execute; returns (result, completion_time).

        ``priority="serve"`` (default) occupies a pool device.
        ``priority="background"`` runs on the deferrable lane: it starts no
        earlier than the pool's next free instant but reserves *no* device
        time — later serve-lane calls are never queued behind it (WFQ/
        priority ordering on a shared fog node; the PR-2 follow-up).
        """
        now = self.clock if now is None else now
        fn = self.registry.get(fn_name)
        if priority == "background":
            start = max(now, min(self.busy_until), self.bg_busy_until)
            t0 = time.perf_counter()
            result = fn(*args, **kw)
            wall = time.perf_counter() - t0
            dur = wall if self.measure else (
                model_time if model_time is not None else wall)
            done = start + dur
            self.bg_busy_until = done
            self.clock = max(self.clock, done)
            self.records.append(ExecutionRecord(fn_name, start, dur,
                                                f"{self.name}/bg"))
            return result, done
        dev, start = self._acquire(now)
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        wall = time.perf_counter() - t0
        dur = wall if self.measure else (
            model_time if model_time is not None else wall)
        done = start + dur
        self.busy_until[dev] = done
        self.clock = max(self.clock, done)
        self.records.append(ExecutionRecord(fn_name, start, dur,
                                            f"{self.name}/dev{dev}"))
        return result, done

    def occupy(self, fn_name: str, *, now: float,
               model_time: float) -> Tuple[float, float]:
        """Reserve device time without running a function.

        Hedged dispatch books the speculative duplicate with this: the
        duplicate occupies a real device (it shows up in utilization and
        billing) but the primary's result is reused bitwise, so there is
        nothing to execute.  Returns ``(start, completion_time)``."""
        dev, start = self._acquire(now)
        done = start + model_time
        self.busy_until[dev] = done
        self.clock = max(self.clock, done)
        self.records.append(ExecutionRecord(fn_name, start, model_time,
                                            f"{self.name}/dev{dev}"))
        return start, done

    def utilization(self, horizon: float) -> float:
        if horizon <= 0:
            return 0.0
        busy = sum(r.duration for r in self.records
                   if r.start >= self.clock - horizon)
        return min(1.0, busy / (horizon * max(self.num_devices, 1)))

    def busy_fraction(self, t0: float, t1: float) -> float:
        """Fraction of the simulated window [t0, t1] this executor's device
        pool spent in service (`GraphScheduler.throughput_report` scores
        the shared fog-batch executor with this over the detect span — a
        starved accelerator shows up here before it shows up in
        frames/sec)."""
        if t1 <= t0:
            return 0.0
        busy = sum(max(0.0, min(r.start + r.duration, t1) - max(r.start, t0))
                   for r in self.records)
        return min(1.0, busy / ((t1 - t0) * max(self.num_devices, 1)))
