"""Checkpointing: flat .npz save/restore for arbitrary pytrees.

Keys encode the tree path; restore rebuilds against a reference tree (so it
works for params, optimizer state, and classifier snapshots alike).  This is
the model-zoo storage backend of the stateful platform (§III.D data store).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore(path: str, like) -> Any:
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    leaves = []
    for key, ref in zip(flat_paths, leaves_like):
        arr = jnp.asarray(data[key])
        if arr.shape != tuple(ref.shape):
            raise ValueError(f"checkpoint shape mismatch at {key}: "
                             f"{arr.shape} vs {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> Dict[str, Any]:
    with open(path + ".meta.json") as f:
        return json.load(f)
