"""Data pipelines: synthetic token streams (LLM training) and video-model
training batches (detector / classifier pre-training)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.vpaas_video import ClassifierConfig, DetectorConfig
from repro.video import synthetic


# ---------------------------------------------------------------------------
# Token streams (language-model substrate)
# ---------------------------------------------------------------------------
@dataclass
class TokenStream:
    """Synthetic but *learnable* token stream: a random first-order Markov
    chain over the vocabulary; next-token structure exists, so training loss
    decreasing is a meaningful signal."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)   # transition table cap
        self._v = v
        self._next = rng.integers(0, v, size=(v, self.branching))

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            toks = np.empty((self.batch_size, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, self._v, self.batch_size)
            choice = rng.integers(0, self.branching,
                                  (self.batch_size, self.seq_len))
            for t in range(self.seq_len):
                toks[:, t + 1] = self._next[toks[:, t], choice[:, t]]
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_for(cfg: ModelConfig, batch_size: int, seq_len: int,
              seed: int = 0) -> Dict[str, np.ndarray]:
    return next(iter(TokenStream(cfg.vocab_size, seq_len, batch_size, seed)))


# ---------------------------------------------------------------------------
# Video-model batches
# ---------------------------------------------------------------------------
def detector_batches(det_cfg: DetectorConfig, batch_size: int, seed: int = 0,
                     content: str = "traffic",
                     degrade: Tuple[float, int] | None = None
                     ) -> Iterator[Dict[str, np.ndarray]]:
    """Frames + gt boxes/labels for detector training.

    ``degrade=(r, q)`` additionally yields codec-degraded frames so the
    detector trains on BOTH qualities (the cloud model must localize on
    low-quality input — protocol RQ1)."""
    rng = np.random.default_rng(seed)
    kinds = list(synthetic.CONTENT_TYPES) if content == "all" else [content]
    while True:
        frames, boxes, labels = [], [], []
        while len(frames) < batch_size:
            ch = synthetic.make_chunk(rng, str(rng.choice(kinds)),
                                      num_frames=2, hw=det_cfg.image_hw)
            for t in range(ch.frames.shape[0]):
                frames.append(ch.frames[t])
                boxes.append(ch.gt_boxes[t])
                labels.append(ch.gt_labels[t])
        yield {"images": np.stack(frames[:batch_size]),
               "gt_boxes": np.stack(boxes[:batch_size]),
               "gt_labels": np.stack(labels[:batch_size])}


def bilinear_resize(img, out_hw):
    """(h, w, c) bilinear resize — matches the serving-side crop kernel."""
    import numpy as np
    h, w = out_hw
    ih, iw = img.shape[:2]
    ys = np.linspace(0, ih - 1, h)
    xs = np.linspace(0, iw - 1, w)
    y0 = np.clip(ys.astype(int), 0, max(ih - 2, 0))
    x0 = np.clip(xs.astype(int), 0, max(iw - 2, 0))
    wy = (ys - y0)[:, None, None]
    wx = (xs - x0)[None, :, None]
    y1 = np.minimum(y0 + 1, ih - 1)
    x1 = np.minimum(x0 + 1, iw - 1)
    a = img[y0][:, x0]
    b = img[y0][:, x1]
    c = img[y1][:, x0]
    d = img[y1][:, x1]
    return (a * (1 - wy) * (1 - wx) + b * (1 - wy) * wx
            + c * wy * (1 - wx) + d * wy * wx).astype(img.dtype)


def classifier_batches(clf_cfg: ClassifierConfig, batch_size: int,
                       seed: int = 0, drift: float = 0.0,
                       box_jitter: float = 0.1
                       ) -> Iterator[Dict[str, np.ndarray]]:
    """Object crops + labels for the fog classifier.

    Crops use bilinear resize and jittered boxes, matching what the serving
    path produces from detector proposals."""
    rng = np.random.default_rng(seed)
    while True:
        crops, labels = [], []
        while len(crops) < batch_size:
            if drift > 0:
                ch = synthetic.drifted_chunk(rng, "traffic", drift=drift,
                                             num_frames=1, hw=(128, 128))
            else:
                ch = synthetic.make_chunk(rng, "traffic", num_frames=1,
                                          hw=(128, 128))
            fh, fw = ch.frames.shape[1:3]
            for i in range(ch.gt_boxes.shape[1]):
                if ch.gt_labels[0, i] < 0:
                    continue
                box = ch.gt_boxes[0, i].copy()
                if box_jitter:
                    size = max(box[2] - box[0], box[3] - box[1])
                    box += rng.uniform(-box_jitter, box_jitter, 4) * size
                x1, y1, x2, y2 = np.clip(box, 0.0, 1.0)
                xa, xb = int(x1 * fw), max(int(x2 * fw), int(x1 * fw) + 2)
                ya, yb = int(y1 * fh), max(int(y2 * fh), int(y1 * fh) + 2)
                crop = ch.frames[0, ya:yb, xa:xb]
                crops.append(bilinear_resize(crop, clf_cfg.crop_hw))
                labels.append(ch.gt_labels[0, i])
        yield {"crops": np.stack(crops[:batch_size]).astype(np.float32),
               "labels": np.asarray(labels[:batch_size], np.int32)}
