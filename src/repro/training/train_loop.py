"""Train-step factories: LLM (pjit, sharded) and video models (single host).

``make_train_step`` returns a pure (params, opt_state, batch) -> ... function
ready for jax.jit with in/out shardings (the launcher supplies those).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.vpaas_video import ClassifierConfig, DetectorConfig
from repro.models import classifier as clf_mod
from repro.models import detector as det_mod
from repro.models import transformer as tfm
from repro.training.optimizer import AdamW, global_norm


# ---------------------------------------------------------------------------
# LLM training
# ---------------------------------------------------------------------------
def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    *,
    impl: str = "ref",
    remat: bool = True,
    act_constraint=None,
    dtype=jnp.float32,
) -> Callable:
    def train_step(params, opt_state, batch):
        def loss(p):
            return tfm.loss_fn(cfg, p, batch, impl=impl, remat=remat,
                               act_constraint=act_constraint, dtype=dtype)

        (total, parts), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_params, new_opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": total, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": global_norm(grads)}
        return new_params, new_opt_state, metrics

    return train_step


def train_llm(cfg: ModelConfig, *, steps: int, batch_size: int, seq_len: int,
              lr: float = 3e-4, seed: int = 0, log_every: int = 10,
              branching: int = 8, callback=None) -> Tuple[Any, list]:
    """Single-host training driver (examples + integration tests)."""
    from repro.training.data import TokenStream

    key = jax.random.PRNGKey(seed)
    params = tfm.init_params(cfg, key)
    opt = AdamW(lr=lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))

    history = []
    stream = iter(TokenStream(cfg.vocab_size, seq_len, batch_size, seed,
                              branching=branching))
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            rec = {"step": step, **{k: float(v) for k, v in metrics.items()}}
            history.append(rec)
            if callback:
                callback(rec)
    return params, history


# ---------------------------------------------------------------------------
# Video-model training (detector / classifier pre-training)
# ---------------------------------------------------------------------------
def train_detector(det_cfg: DetectorConfig, *, steps: int = 300,
                   batch_size: int = 16, lr: float = 1e-3, seed: int = 0,
                   content: str = "all", degrade: bool = True,
                   callback=None):
    """``degrade=True`` trains on a mix of clean and codec-degraded frames —
    the cloud detector must keep its localization power on low-quality
    video (protocol Key Observation 2)."""
    import numpy as np

    from repro.training.data import detector_batches
    from repro.video import codec

    rng = np.random.default_rng(seed + 7)
    params = det_mod.init_detector(det_cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss(p):
            return det_mod.detector_loss(det_cfg, p, batch["images"],
                                         batch["gt_boxes"],
                                         batch["gt_labels"])
        (total, parts), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": total, **parts}

    history = []
    gen = detector_batches(det_cfg, batch_size, seed, content)
    qualities = [(1.0, 10), (0.8, 30), (0.8, 36), (0.6, 36), (1.0, 26)]
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        if degrade and step % 2 == 1:   # alternate clean / degraded batches
            r, q = qualities[int(rng.integers(len(qualities)))]
            batch["images"] = codec.encode(batch["images"], r, q).frames
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 25 == 0 or step == steps - 1:
            rec = {"step": step, **{k: float(v) for k, v in m.items()}}
            history.append(rec)
            if callback:
                callback(rec)
    return params, history


def train_classifier(clf_cfg: ClassifierConfig, *, steps: int = 300,
                     batch_size: int = 64, lr: float = 1e-3, seed: int = 0,
                     drift: float = 0.0, callback=None):
    from repro.training.data import classifier_batches

    params = clf_mod.init_classifier(clf_cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, weight_decay=1e-4)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss(p):
            return clf_mod.classifier_loss(clf_cfg, p, batch["crops"],
                                           batch["labels"])
        (total, parts), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": total, **parts}

    history = []
    gen = classifier_batches(clf_cfg, batch_size, seed, drift=drift)
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 25 == 0 or step == steps - 1:
            rec = {"step": step, **{k: float(v) for k, v in m.items()}}
            history.append(rec)
            if callback:
                callback(rec)
    return params, history
