from repro.training import checkpoint, data, optimizer, train_loop  # noqa: F401
