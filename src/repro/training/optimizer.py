"""Optimizers (AdamW, SGD+momentum) and LR schedules, from scratch.

State is a pytree mirroring params; everything jits and shards (optimizer
state inherits the parameter sharding — ZeRO-style under the FSDP rules).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def _lr(self, step: jax.Array) -> jax.Array:
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** t)
        nu_hat_scale = 1.0 / (1 - b2 ** t)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu)


@dataclass(frozen=True)
class SGDM:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-2
    momentum: float = 0.9

    def init(self, params):
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(lambda p: jnp.zeros_like(
                              p, jnp.float32), params), None)

    def update(self, grads, state, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mu = jax.tree.map(lambda m, g: self.momentum * m + g.astype(m.dtype),
                          state.mu, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_params, AdamWState(step, mu, None)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr)
