"""Accuracy metrics: F1 with IoU matching (paper §VI evaluation metric)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


def iou_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """a (N,4), b (M,4) xyxy -> (N, M)."""
    ax1, ay1, ax2, ay2 = [a[:, None, i] for i in range(4)]
    bx1, by1, bx2, by2 = [b[None, :, i] for i in range(4)]
    iw = np.maximum(np.minimum(ax2, bx2) - np.maximum(ax1, bx1), 0.0)
    ih = np.maximum(np.minimum(ay2, by2) - np.maximum(ay1, by1), 0.0)
    inter = iw * ih
    area_a = np.maximum(ax2 - ax1, 0) * np.maximum(ay2 - ay1, 0)
    area_b = np.maximum(bx2 - bx1, 0) * np.maximum(by2 - by1, 0)
    return inter / np.maximum(area_a + area_b - inter, 1e-9)


@dataclass
class F1Accumulator:
    iou_threshold: float = 0.5
    tp: int = 0
    fp: int = 0
    fn: int = 0

    def update(self, pred_boxes: np.ndarray, pred_labels: np.ndarray,
               gt_boxes: np.ndarray, gt_labels: np.ndarray) -> None:
        """One frame. gt_labels == -1 are padding; preds are pre-filtered."""
        keep = gt_labels >= 0
        gt_boxes, gt_labels = gt_boxes[keep], gt_labels[keep]
        n, m = len(pred_boxes), len(gt_boxes)
        if m == 0:
            self.fp += n
            return
        if n == 0:
            self.fn += m
            return
        iou = iou_np(np.asarray(pred_boxes), np.asarray(gt_boxes))
        matched_gt = set()
        order = np.argsort(-iou.max(axis=1))
        for i in order:
            j = int(np.argmax(np.where(
                [jj not in matched_gt for jj in range(m)], iou[i], -1.0)))
            if iou[i, j] >= self.iou_threshold and j not in matched_gt:
                matched_gt.add(j)
                if pred_labels[i] == gt_labels[j]:
                    self.tp += 1
                else:
                    self.fp += 1
                    self.fn += 1
            else:
                self.fp += 1
        self.fn += m - len(matched_gt)

    @property
    def precision(self) -> float:
        return self.tp / max(self.tp + self.fp, 1)

    @property
    def recall(self) -> float:
        return self.tp / max(self.tp + self.fn, 1)

    @property
    def f1(self) -> float:
        p, r = self.precision, self.recall
        return 2 * p * r / max(p + r, 1e-9)

    def summary(self) -> Dict[str, float]:
        return {"precision": self.precision, "recall": self.recall,
                "f1": self.f1, "tp": self.tp, "fp": self.fp, "fn": self.fn}


def localization_recall(pred_boxes: np.ndarray, gt_boxes: np.ndarray,
                        gt_labels: np.ndarray,
                        iou_threshold: float = 0.5) -> float:
    """Class-agnostic recall (measures Key Obs 2: localization power)."""
    keep = gt_labels >= 0
    gt = gt_boxes[keep]
    if len(gt) == 0:
        return 1.0
    if len(pred_boxes) == 0:
        return 0.0
    iou = iou_np(np.asarray(pred_boxes), np.asarray(gt))
    return float(np.mean(iou.max(axis=0) >= iou_threshold))
