"""Quality control: the JAX codec behind F_v(r, q) (paper Eq. 2).

The paper adjusts video quality with FFmpeg (resolution scale + H.264 QP).
We reproduce the same byte/quality trade-off with a real transform codec:

  encode(frames, r, q):
    1. downscale by resolution factor r  (bilinear)
    2. 8x8 block DCT per channel
    3. uniform quantization with H.264-style step  2^((q - 4) / 6)
    4. byte estimate from an exp-Golomb-style code-length model over the
       quantized coefficients (derived from data, not hard-coded)
    5. decode = dequantize -> inverse DCT -> upscale back

The protocol layer consumes only (frames_out, bytes) — exactly the F_v(r, q)
abstraction of Eq. 2.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8


class EncodedChunk(NamedTuple):
    frames: jax.Array           # decoded (degraded) frames (T, H, W, 3)
    nbytes: jax.Array           # scalar float: estimated compressed size
    r: float
    q: int


@functools.lru_cache(maxsize=None)
def _dct_matrix(n: int = BLOCK) -> np.ndarray:
    k = np.arange(n)
    mat = np.sqrt(2.0 / n) * np.cos(np.pi * (2 * k[None, :] + 1)
                                    * k[:, None] / (2 * n))
    mat[0] /= np.sqrt(2.0)
    return mat.astype(np.float32)


def qp_to_step(q: jax.Array | float) -> jax.Array:
    """H.264-style quantization step (doubles every 6 QP)."""
    return jnp.asarray(2.0 ** ((jnp.asarray(q, jnp.float32) - 4.0) / 6.0)) / 64.0


def _blockify(x: jax.Array) -> jax.Array:
    """(T, H, W, C) -> (T, H/8, W/8, C, 8, 8)."""
    t, h, w, c = x.shape
    x = x.reshape(t, h // BLOCK, BLOCK, w // BLOCK, BLOCK, c)
    return x.transpose(0, 1, 3, 5, 2, 4)


def _unblockify(x: jax.Array) -> jax.Array:
    t, hb, wb, c, _, _ = x.shape
    x = x.transpose(0, 1, 4, 2, 5, 3)
    return x.reshape(t, hb * BLOCK, wb * BLOCK, c)


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, Tuple[int, int]]:
    t, h, w, c = x.shape
    ph = (-h) % BLOCK
    pw = (-w) % BLOCK
    return jnp.pad(x, ((0, 0), (0, ph), (0, pw), (0, 0)), "edge"), (h, w)


def code_length_bits(coef: jax.Array) -> jax.Array:
    """Exp-Golomb-style bit cost of integer coefficients (byte model)."""
    a = jnp.abs(coef)
    bits = jnp.where(a > 0, 2.0 * jnp.ceil(jnp.log2(a + 1.0)) + 1.0, 0.0)
    # run-length proxy for zeros: ~0.06 bits per zero coefficient
    bits = bits + jnp.where(a == 0, 0.0625, 0.0)
    return jnp.sum(bits)


@functools.partial(jax.jit, static_argnames=("r",))
def encode(frames: jax.Array, r: float, q: jax.Array | int) -> EncodedChunk:
    """frames (T, H, W, 3) float in [0,1]; r in (0,1]; q = QP (0..51)."""
    t, h0, w0, c = frames.shape
    if r != 1.0:
        hs, ws = max(BLOCK, int(h0 * r)), max(BLOCK, int(w0 * r))
        small = jax.image.resize(frames, (t, hs, ws, c), "linear")
    else:
        small = frames
    small, (h, w) = _pad_to_block(small)

    dct = jnp.asarray(_dct_matrix())
    blocks = _blockify(small - 0.5)
    coef = jnp.einsum("ij,...jk,lk->...il", dct, blocks, dct)
    step = qp_to_step(q)
    quant = jnp.round(coef / step)

    nbits = code_length_bits(quant)
    # decode side
    deq = quant * step
    rec = jnp.einsum("ji,...jk,kl->...il", dct, deq, dct) + 0.5
    rec = _unblockify(rec)[:, :h, :w]
    if r != 1.0:
        rec = jax.image.resize(rec, (t, h0, w0, c), "linear")
    rec = jnp.clip(rec, 0.0, 1.0)
    return EncodedChunk(rec, nbits / 8.0, r, int(q) if not hasattr(q, "shape")
                        else q)


@functools.partial(jax.jit, static_argnames=("r",))
def encode_inter(frames: jax.Array, r: float, q) -> EncodedChunk:
    """Closed-loop inter-frame (P-frame) coding: each frame encodes the
    DCT-quantized residual against the previous *reconstructed* frame, so
    static content costs ~nothing — the H.264 temporal-compression behavior
    the intra-only ``encode`` misses.  Same (frames, bytes) contract."""
    t, h0, w0, c = frames.shape
    if r != 1.0:
        hs, ws = max(BLOCK, int(h0 * r)), max(BLOCK, int(w0 * r))
        small = jax.image.resize(frames, (t, hs, ws, c), "linear")
    else:
        small = frames
    small, (h, w) = _pad_to_block(small)
    dct = jnp.asarray(_dct_matrix())
    step = qp_to_step(q)

    def one(prev_rec, frame):
        resid = frame - prev_rec
        blocks = _blockify(resid[None])
        coef = jnp.einsum("ij,...jk,lk->...il", dct, blocks, dct)
        quant = jnp.round(coef / step)
        bits = code_length_bits(quant)
        rec_res = jnp.einsum("ji,...jk,kl->...il", dct, quant * step, dct)
        rec = jnp.clip(prev_rec + _unblockify(rec_res)[0], 0.0, 1.0)
        return rec, (rec, bits)

    gray = jnp.full_like(small[0], 0.5)       # intra-frame = residual vs gray
    _, (recs, bits) = jax.lax.scan(one, gray, small)
    recs = recs[:, :h, :w]
    if r != 1.0:
        recs = jax.image.resize(recs, (t, h0, w0, c), "linear")
    return EncodedChunk(jnp.clip(recs, 0.0, 1.0), jnp.sum(bits) / 8.0, r,
                        int(q) if not hasattr(q, "shape") else q)


def raw_bytes(frames: jax.Array) -> float:
    """Uncompressed size (the MPEG/original-video bandwidth reference)."""
    return float(np.prod(frames.shape))  # 1 byte/channel-pixel


def psnr(a: jax.Array, b: jax.Array) -> jax.Array:
    mse = jnp.mean((a - b) ** 2)
    return -10.0 * jnp.log10(jnp.maximum(mse, 1e-10))
