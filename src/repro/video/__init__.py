from repro.video import codec, metrics, synthetic  # noqa: F401
