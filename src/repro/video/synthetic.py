"""Procedural video datasets with ground truth (DashCam / Drone / Traffic).

Classes are distinguished by *texture* (class-specific stripe frequency and
orientation), not by silhouette: aggressive QP quantization destroys the
high-frequency texture (classification signal) while the object silhouette
(localization signal) survives — this is how the paper's Key Observation 2
emerges from data here instead of being hard-coded.

Content types mirror the paper's Table I datasets:
  * dashcam — few, large, fast objects
  * drone   — many small objects, slow global drift
  * traffic — many medium objects, slow, dense
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

import numpy as np

NUM_CLASSES = 8


@dataclass(frozen=True)
class ContentType:
    name: str
    num_objects: Tuple[int, int]      # min/max simultaneous objects
    size: Tuple[float, float]         # min/max object size (frame fraction)
    speed: Tuple[float, float]        # min/max speed (frame fraction / frame)


CONTENT_TYPES: Dict[str, ContentType] = {
    "dashcam": ContentType("dashcam", (2, 4), (0.18, 0.30), (0.010, 0.030)),
    "drone": ContentType("drone", (4, 8), (0.08, 0.14), (0.004, 0.012)),
    "traffic": ContentType("traffic", (5, 10), (0.10, 0.18), (0.003, 0.010)),
}


@dataclass
class VideoChunk:
    frames: np.ndarray                # (T, H, W, 3) float32 in [0,1]
    gt_boxes: np.ndarray              # (T, M, 4) xyxy in [0,1]
    gt_labels: np.ndarray             # (T, M) int32, -1 padding
    content: str


def _texture(cls: int, yy: np.ndarray, xx: np.ndarray,
             rng: np.random.Generator, drift: float = 0.0) -> np.ndarray:
    """Class-signature texture: 4 high-frequency pattern types x 2 bands.

    The class is encoded ONLY in fine texture (wavelength 2.7-4 px at the
    native 128 px resolution); orientation and phase are random per instance.
    Resolution downscaling + QP quantization destroy exactly this band while
    the object silhouette survives -> Key Observation 2 emerges from data.

    ``drift`` migrates the two frequency bands toward each other's position
    (object appearances change over time, §V data drift): at drift=1 the
    bands have fully SWAPPED.  Localization is untouched; a classifier
    trained at drift=0 systematically mislabels the frequency bit — and a
    *last-layer* update can fully recover it (the features still separate
    the bands; only the readout mapping is stale).  Avoid drift=0.5, where
    the bands coincide and no readout can help."""
    ptype, fbit = divmod(cls, 2)
    freq = 32.0 + 16.0 * drift if fbit == 0 else 48.0 - 16.0 * drift
    angle = rng.uniform(0, np.pi)
    phase0 = rng.uniform(0, 2 * np.pi)
    u = np.cos(angle) * xx + np.sin(angle) * yy
    v = -np.sin(angle) * xx + np.cos(angle) * yy
    su = np.sin(2 * np.pi * freq * u + phase0)
    sv = np.sin(2 * np.pi * freq * v + phase0)
    if ptype == 0:       # stripes
        pat = su
    elif ptype == 1:     # checkerboard
        pat = su * sv
    elif ptype == 2:     # dots (sparse bright spots)
        pat = np.where((su > 0.3) & (sv > 0.3), 1.0, -0.6)
    else:                # cross-hatch
        pat = 0.5 * (np.sign(su) + np.sign(sv))
    return 0.5 + 0.45 * np.clip(pat, -1.0, 1.0)


# Only TWO tints across eight classes: color alone identifies just one bit;
# the class signal lives in the high-frequency texture, which QP
# quantization destroys (-> Key Observation 2 emerges from data).
_CLASS_TINT = np.array(
    [[0.85, 0.55, 0.45], [0.5, 0.65, 0.85]], dtype=np.float32)


def class_tint(cls: int) -> np.ndarray:
    # tint follows the PATTERN-TYPE parity, never the frequency bit: the
    # frequency band stays the only signal for the low class bit, so it is
    # (a) destroyed by LQ encoding and (b) shifted by data drift
    return _CLASS_TINT[(cls // 2) % 2]


def make_chunk(
    rng: np.random.Generator,
    content: str = "traffic",
    *,
    num_frames: int = 16,
    hw: Tuple[int, int] = (128, 128),
    max_objects: int = 10,
    texture_drift: float = 0.0,
) -> VideoChunk:
    ct = CONTENT_TYPES[content]
    h, w = hw
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w),
                         indexing="ij")

    # background: smooth low-frequency gradient + mild noise
    bg_phase = rng.uniform(0, 2 * np.pi, 3)
    bg = np.stack([0.45 + 0.15 * np.sin(2 * np.pi * (0.7 * xx + 0.4 * yy)
                                        + p) for p in bg_phase], -1)

    k = int(rng.integers(ct.num_objects[0], ct.num_objects[1] + 1))
    k = min(k, max_objects)
    cls = rng.integers(0, NUM_CLASSES, k)
    size = rng.uniform(*ct.size, k)
    pos = rng.uniform(0.15, 0.85, (k, 2))
    ang = rng.uniform(0, 2 * np.pi, k)
    spd = rng.uniform(*ct.speed, k)
    vel = np.stack([np.cos(ang), np.sin(ang)], -1) * spd[:, None]

    frames = np.empty((num_frames, h, w, 3), np.float32)
    boxes = np.full((num_frames, max_objects, 4), 0.0, np.float32)
    labels = np.full((num_frames, max_objects), -1, np.int32)

    tex = [_texture(int(c), yy, xx, rng, drift=texture_drift) for c in cls]
    for t in range(num_frames):
        img = bg + rng.normal(0, 0.015, bg.shape).astype(np.float32)
        for i in range(k):
            cxy = pos[i] + vel[i] * t
            cxy = 0.5 + 0.5 * np.sin(np.pi * (cxy - 0.5))   # soft bounce
            half = size[i] / 2
            x1, y1 = cxy[0] - half, cxy[1] - half
            x2, y2 = cxy[0] + half, cxy[1] + half
            mask = ((xx >= x1) & (xx <= x2) & (yy >= y1) & (yy <= y2))
            col = tex[i][..., None] * class_tint(int(cls[i]))
            img = np.where(mask[..., None], col, img)
            boxes[t, i] = np.clip([x1, y1, x2, y2], 0.0, 1.0)
            labels[t, i] = cls[i]
        frames[t] = np.clip(img, 0.0, 1.0)
    return VideoChunk(frames, boxes, labels, content)


def dataset(
    seed: int,
    content: str,
    num_chunks: int,
    **kw,
) -> List[VideoChunk]:
    rng = np.random.default_rng(seed)
    return [make_chunk(rng, content, **kw) for _ in range(num_chunks)]


def chunk_stream(seed: int, content: str, **kw) -> Iterator[VideoChunk]:
    rng = np.random.default_rng(seed)
    while True:
        yield make_chunk(rng, content, **kw)


def drifted_chunk(rng: np.random.Generator, content: str = "traffic",
                  drift: float = 0.5, **kw) -> VideoChunk:
    """Data-drift variant (§V): class textures shift bands over time (new
    object appearances).  Silhouettes — and hence the cloud detector's
    localization — are untouched; the fog classifier trained at drift=0
    degrades and the HITL loop must recover it (Fig. 13a).

    ``drift`` in [0,1] interpolates toward the shifted distribution.
    """
    chunk = make_chunk(rng, content, texture_drift=drift, **kw)
    # plus a mild illumination component
    gain = 1.0 - 0.08 * drift
    frames = np.clip(gain * chunk.frames, 0.0, 1.0)
    return VideoChunk(frames.astype(np.float32), chunk.gt_boxes,
                      chunk.gt_labels, chunk.content)
