"""Target-hardware constants (TPU v5e) for the roofline model."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float       # FLOP/s per chip
    hbm_bandwidth: float         # bytes/s per chip
    hbm_bytes: float             # HBM capacity per chip
    ici_link_bandwidth: float    # bytes/s per link


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16e9,
    ici_link_bandwidth=50e9,
)
