"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch, shape, mesh):

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
parsed from the post-SPMD HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op we take the (per-device)
result tensor size and apply the ring-algorithm byte multiplier:

  all-gather         result ~ full gathered tile     x (g-1)/g  ~ 1
  all-reduce         2 x result (reduce + broadcast phases)
  reduce-scatter     result x (g-1)  (operand = g x result is streamed)
  all-to-all         result x (g-1)/g
  collective-permute result

where g = replica-group size parsed from the op attributes (fallback 2).
cost_analysis FLOPs are per-device for SPMD modules, so `chips` stays in the
denominator only through per-chip peaks.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.roofline.hw import ChipSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


_MULTIPLIER = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Returns (total per-device link bytes, per-op-kind breakdown)."""
    per_kind: Dict[str, float] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        if "-done(" in line:   # async pair: count the -start only
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _tensor_bytes(type_str)
        g = _group_size(line)
        moved = nbytes * _MULTIPLIER[kind](g)
        per_kind[kind] = per_kind.get(kind, 0.0) + moved
    return sum(per_kind.values()), per_kind


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]
    model_flops: float
    bytes_per_device: float = 0.0
    peak_memory_per_device: float = 0.0

    chip: ChipSpec = TPU_V5E

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.chip.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.chip.hbm_bandwidth

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.chip.ici_link_bandwidth

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("chip")
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (forward-only), N = active
    params, D = tokens processed in the step."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch * 1          # decode: one token


def analyze_compiled(compiled, lowered_text: str, *, arch: str, shape,
                     cfg, mesh_name: str, chips: int) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll, breakdown = collective_bytes(lowered_text)
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = (getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0))
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, coll_bytes=coll,
        coll_breakdown=breakdown, model_flops=model_flops(cfg, shape),
        peak_memory_per_device=peak)
