"""CloudSeg baseline: ship very-low-resolution video; the cloud runs a
super-resolution model before detection [Wang et al., HotCloud'19].

The SR stage is a cloud-side x2 upscale (cubic + unsharp) standing in for
the CARN model; its billing shows up as the extra-model multiplier (the
paper: "the cost is doubled compared to that incurred by our system").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineResult, run_detector,
                                    threshold_detections)
from repro.configs.vpaas_video import DetectorConfig
from repro.core.bandwidth import (CLIENT, CLOUD, CostModel, DeviceProfile,
                                  LatencyBreakdown, NetworkModel)
from repro.video import codec


def super_resolve(frames: jax.Array, out_hw) -> jax.Array:
    """x2-style SR recovery: cubic upscale + unsharp masking."""
    t, _, _, c = frames.shape
    up = jax.image.resize(frames, (t, out_hw[0], out_hw[1], c), "cubic")
    blur = jax.image.resize(
        jax.image.resize(up, (t, out_hw[0] // 2, out_hw[1] // 2, c),
                         "linear"),
        (t, out_hw[0], out_hw[1], c), "linear")
    return jnp.clip(up + 0.6 * (up - blur), 0.0, 1.0)


@dataclass
class CloudSegBaseline:
    det_cfg: DetectorConfig
    # paper §VI uses RS 0.35 at 1080p; our frames are 128 px, so the same
    # absolute object resolution corresponds to a milder scale factor
    r: float = 0.6
    q: int = 20
    theta_loc: float = 0.5
    theta_cls: float = 0.5
    network: NetworkModel = field(default_factory=NetworkModel)
    client: DeviceProfile = CLIENT
    cloud: DeviceProfile = CLOUD
    cost_model: CostModel = field(
        default_factory=lambda: CostModel(extra_model_multiplier=2.0))

    def process_chunk(self, det_params, frames_hq: np.ndarray,
                      **_) -> BaselineResult:
        f, h, w, _ = frames_hq.shape
        enc = codec.encode_inter(jnp.asarray(frames_hq), self.r, self.q)
        # the codec returns frames upscaled back to (h, w); emulate the SR
        # recovery on the degraded signal
        recovered = super_resolve(enc.frames, (h, w))
        det = run_detector(self.det_cfg, det_params, recovered)
        boxes, labels, valid = threshold_detections(
            det, self.theta_loc, self.theta_cls)
        lat = LatencyBreakdown(
            quality_control=self.client.encode_time(f),
            transmission=self.network.wan_time(float(enc.nbytes)),
            # SR + detection: two cloud model passes
            cloud_inference=2.0 * self.cloud.detect_time(f))
        return BaselineResult(boxes, labels, valid, float(enc.nbytes), f,
                              2.0, lat)
