"""DDS baseline: server-driven two-round streaming [Du et al., SIGCOMM'20].

Round 1: low-quality chunk -> cloud detector -> confident labels + uncertain
regions.  Round 2: the uncertain regions are re-encoded in HIGH quality,
shipped again, and the cloud detector runs a second pass on the composited
frames.  Both rounds bill cloud inference (the paper's cost critique).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineResult, run_detector,
                                    threshold_detections)
from repro.configs.vpaas_video import DetectorConfig
from repro.core import regions as reg
from repro.core.bandwidth import (CLIENT, CLOUD, DeviceProfile,
                                  LatencyBreakdown, NetworkModel)
from repro.video import codec


@dataclass
class DDSBaseline:
    det_cfg: DetectorConfig
    # paper §VI: round-1 QP 36 / RS 0.8, round-2 QP 26 / RS 0.8
    q1: int = 36
    r1: float = 0.8
    q2: int = 26
    r2: float = 0.8
    theta_cls: float = 0.85
    theta_loc: float = 0.5
    theta_iou: float = 0.3
    theta_back: float = 0.5
    network: NetworkModel = field(default_factory=NetworkModel)
    client: DeviceProfile = CLIENT
    cloud: DeviceProfile = CLOUD

    def process_chunk(self, det_params, frames_hq: np.ndarray,
                      **_) -> BaselineResult:
        f = frames_hq.shape[0]
        fhq = jnp.asarray(frames_hq)

        # ---- round 1: low quality ----
        enc1 = codec.encode_inter(fhq, self.r1, self.q1)
        det1 = run_detector(self.det_cfg, det_params, enc1.frames)
        split = reg.split_regions(
            det1, theta_cls=self.theta_cls, theta_loc=self.theta_loc,
            theta_iou=self.theta_iou, theta_back=self.theta_back)

        # ---- round 2: uncertain regions in high quality ----
        enc2 = codec.encode_inter(fhq, self.r2, self.q2)
        mask = np.zeros(frames_hq.shape[:3] + (1,), np.float32)
        pv = np.asarray(split.prop_valid)
        pb = np.asarray(split.prop_boxes)
        h, w = frames_hq.shape[1:3]
        area = 0.0
        for t in range(f):
            for i in np.nonzero(pv[t])[0]:
                x1, y1, x2, y2 = pb[t, i]
                xa, xb = int(x1 * w), max(int(x2 * w), int(x1 * w) + 1)
                ya, yb = int(y1 * h), max(int(y2 * h), int(y1 * h) + 1)
                mask[t, ya:yb, xa:xb] = 1.0
                area += (xb - xa) * (yb - ya)
        # region bytes: hi-q rate scaled by covered area fraction
        frac = area / (f * h * w)
        round2_bytes = float(enc2.nbytes) * frac
        composite = (np.asarray(enc2.frames) * mask
                     + np.asarray(enc1.frames) * (1 - mask))
        det2 = run_detector(self.det_cfg, det_params,
                            jnp.asarray(composite))
        boxes, labels, valid = threshold_detections(
            det2, self.theta_loc, self.theta_cls)

        # merge round-1 confident labels
        acc_v = np.asarray(split.acc_valid)
        labels = np.where(acc_v, np.asarray(split.acc_labels), labels)
        valid = valid | acc_v

        total_bytes = float(enc1.nbytes) + round2_bytes
        rounds = 1.0 + float(pv.any(axis=1).mean())   # frames with round 2
        lat = LatencyBreakdown(
            quality_control=2.0 * self.client.encode_time(f),
            transmission=(self.network.wan_time(float(enc1.nbytes))
                          + self.network.wan_time(round2_bytes)),
            cloud_inference=rounds * self.cloud.detect_time(f))
        return BaselineResult(np.asarray(boxes), labels, valid, total_bytes,
                              f, rounds, lat)
