from repro.baselines.common import BaselineResult  # noqa: F401
from repro.baselines.mpeg import MPEGBaseline  # noqa: F401
from repro.baselines.glimpse import GlimpseBaseline  # noqa: F401
from repro.baselines.cloudseg import CloudSegBaseline  # noqa: F401
from repro.baselines.dds import DDSBaseline  # noqa: F401
