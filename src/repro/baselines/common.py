"""Shared result structure + detection post-processing for baselines."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.vpaas_video import DetectorConfig
from repro.core.bandwidth import LatencyBreakdown
from repro.models import detector as det_mod


@dataclass
class BaselineResult:
    boxes: np.ndarray            # (F, N, 4)
    labels: np.ndarray           # (F, N)
    valid: np.ndarray            # (F, N) bool
    wan_bytes: float
    cloud_frames: int
    cloud_rounds: float          # billing rounds (DDS > 1, CloudSeg uses x2)
    latency: LatencyBreakdown

    def detections(self, frame: int) -> Tuple[np.ndarray, np.ndarray]:
        keep = self.valid[frame]
        return self.boxes[frame][keep], self.labels[frame][keep]


def threshold_detections(det, theta_loc: float = 0.5,
                         theta_cls: float = 0.5, nms_iou: float = 0.45):
    """Plain cloud-only acceptance rule (+NMS) for baseline detectors."""
    import jax
    from repro.kernels import ops

    loc = np.asarray(det["loc_scores"])
    probs = np.asarray(det["cls_probs"])
    boxes = np.asarray(det["boxes"])
    labels = probs.argmax(-1).astype(np.int64)
    valid = (loc >= theta_loc) & (probs.max(-1) >= theta_cls)
    keep = jax.vmap(lambda b, s, v: ops.nms_mask(
        b, s, v, iou_threshold=nms_iou))(
        det["boxes"], det["loc_scores"] * det["cls_probs"].max(-1),
        jnp.asarray(valid))
    return boxes, labels, np.asarray(keep)


def run_detector(det_cfg: DetectorConfig, det_params, frames) -> dict:
    return det_mod.detect(det_cfg, det_params, jnp.asarray(frames))
