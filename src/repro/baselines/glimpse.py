"""Glimpse baseline (client-driven): pixel-difference frame filter +
client-side tracking between triggered frames [Chen et al., SenSys'15].

Frames whose pixel delta vs the last *sent* frame exceeds a threshold are
shipped to the cloud; in between, the last detections are carried forward by
a global-motion estimate (our stand-in for Glimpse's feature tracker, per
the paper's note that their re-implementation uses an OpenCV tracker).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineResult, run_detector,
                                    threshold_detections)
from repro.configs.vpaas_video import DetectorConfig
from repro.core.bandwidth import (CLIENT, CLOUD, DeviceProfile,
                                  LatencyBreakdown, NetworkModel)
from repro.video import codec


def _global_shift(prev: np.ndarray, cur: np.ndarray) -> np.ndarray:
    """Coarse global motion (dx, dy) in [0,1] units via argmax correlation
    of downsampled grayscale images (cheap client-side tracking)."""
    def gray_small(x):
        g = x.mean(-1)
        return g[::4, ::4]
    a, b = gray_small(prev), gray_small(cur)
    fa, fb = np.fft.rfft2(a), np.fft.rfft2(b)
    corr = np.fft.irfft2(fa.conj() * fb, a.shape)
    dy, dx = np.unravel_index(np.argmax(corr), corr.shape)
    h, w = a.shape
    if dy > h // 2:
        dy -= h
    if dx > w // 2:
        dx -= w
    return np.array([dx * 4 / prev.shape[1], dy * 4 / prev.shape[0]])


@dataclass
class GlimpseBaseline:
    det_cfg: DetectorConfig
    diff_threshold: float = 0.02   # mean abs pixel delta trigger
    q: int = 26
    r: float = 1.0
    theta_loc: float = 0.5
    theta_cls: float = 0.5
    network: NetworkModel = field(default_factory=NetworkModel)
    client: DeviceProfile = CLIENT
    cloud: DeviceProfile = CLOUD

    def process_chunk(self, det_params, frames_hq: np.ndarray,
                      **_) -> BaselineResult:
        f, n = frames_hq.shape[0], self.det_cfg.max_regions
        gh, gw = self.det_cfg.grid_hw
        n = gh * gw
        boxes = np.zeros((f, n, 4), np.float32)
        labels = np.zeros((f, n), np.int64)
        valid = np.zeros((f, n), bool)

        total_bytes = 0.0
        sent = 0
        last_sent = None
        last_boxes = np.zeros((n, 4), np.float32)
        last_labels = np.zeros((n,), np.int64)
        last_valid = np.zeros((n,), bool)

        for t in range(f):
            frame = frames_hq[t]
            trigger = (last_sent is None or np.mean(
                np.abs(frame - last_sent)) > self.diff_threshold)
            if trigger:
                enc = codec.encode(jnp.asarray(frame[None]), self.r, self.q)
                total_bytes += float(enc.nbytes)
                det = run_detector(self.det_cfg, det_params, enc.frames)
                b, l, v = threshold_detections(det, self.theta_loc,
                                               self.theta_cls)
                last_boxes, last_labels, last_valid = b[0], l[0], v[0]
                last_sent = frame
                sent += 1
            else:
                shift = _global_shift(last_sent, frame)
                moved = last_boxes.copy()
                moved[:, [0, 2]] += shift[0]
                moved[:, [1, 3]] += shift[1]
                last_boxes = np.clip(moved, 0.0, 1.0)
            boxes[t], labels[t], valid[t] = (last_boxes, last_labels,
                                             last_valid)

        lat = LatencyBreakdown(
            quality_control=self.client.encode_time(sent),
            transmission=self.network.wan_time(total_bytes),
            cloud_inference=self.cloud.detect_time(sent))
        return BaselineResult(boxes, labels, valid, total_bytes, sent, 1.0,
                              lat)
