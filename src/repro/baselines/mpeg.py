"""MPEG baseline: stream near-original-quality video to the cloud."""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import (BaselineResult, run_detector,
                                    threshold_detections)
from repro.configs.vpaas_video import DetectorConfig
from repro.core.bandwidth import (CLIENT, CLOUD, DeviceProfile,
                                  LatencyBreakdown, NetworkModel)
from repro.video import codec


@dataclass
class MPEGBaseline:
    det_cfg: DetectorConfig
    q: int = 10                  # near-lossless
    r: float = 1.0
    theta_loc: float = 0.5
    theta_cls: float = 0.5
    network: NetworkModel = field(default_factory=NetworkModel)
    client: DeviceProfile = CLIENT
    cloud: DeviceProfile = CLOUD

    def process_chunk(self, det_params, frames_hq: np.ndarray,
                      **_) -> BaselineResult:
        enc = codec.encode_inter(jnp.asarray(frames_hq), self.r, self.q)
        det = run_detector(self.det_cfg, det_params, enc.frames)
        boxes, labels, valid = threshold_detections(
            det, self.theta_loc, self.theta_cls)
        f = frames_hq.shape[0]
        lat = LatencyBreakdown(
            quality_control=self.client.encode_time(f),   # client encodes
            transmission=self.network.wan_time(float(enc.nbytes)),
            cloud_inference=self.cloud.detect_time(f))
        return BaselineResult(boxes, labels, valid, float(enc.nbytes), f,
                              1.0, lat)
